"""Fleet engine: N live sensors through ONE vmapped/jitted step core.

The paper frames the architecture as a building block for *distributed
space surveillance networks*, and event-based SSA work (Afshar et al.
1911.08730; Ussa et al. 2007.11404) gets its payoff from many
co-observing sensors. :class:`FleetPipeline` is the serving-shaped
driver for that: the per-sensor streaming carry (:class:`StreamState`)
is lifted into a batched :class:`FleetState` — stacked event atlases,
stacked tracker states, and one host-side dual-threshold cursor per
sensor — and every :meth:`FleetPipeline.feed` drives *all* sensors
through a single ``jit(vmap(core))`` dispatch.

Design invariants:

* **Bit-identity.** Per-sensor outputs equal N independent
  :class:`~repro.core.pipeline.stream.StreamingPipeline` runs exactly —
  scores, tracks, window stats — for ANY interleaving of feeds
  (including idle sensors and chunks splitting a window). The step core
  is window-isolated, so batching sensors along a vmap axis cannot mix
  them; the only subtlety is ragged window counts per feed, handled by
  right-padding each sensor to the feed's max window count with
  all-invalid windows. Padded windows write nothing observable to the
  atlas (no valid events -> no leader pixels -> zero-encoded dump-row
  writes only, and a zero encoding fails every tag check) and the
  tracker carry for the next feed is re-selected at each sensor's last
  *real* window, so the padding coast never leaks into sensor state.
* **Tag accounting.** Tags advance per sensor by the number of real
  windows — identical to the single-sensor stream — even though padded
  windows transiently occupy the tags just past them; those tags carry
  no stale pixels, so their reuse next feed is safe. Epoch rollover
  (atlas slice re-zeroed, tag reset) is decided per sensor on host and
  applied by a tiny donated pre-step only on the rare feeds that roll.
* **Sharding.** Carries have the sensor dim leading, so they shard 1:1
  over the ``sensor`` mesh axis (:mod:`repro.distributed.sharding`):
  ``FleetPipeline(..., mesh=...)`` places the carry with
  ``NamedSharding`` and runs the step under the mesh so each device
  serves ``S / axis_size`` sensors with no cross-device collective. The
  stacked atlas is donated, like the single-sensor stream's.
* **Slot pool.** The batched carry is a pool of recyclable slots, not a
  frozen sensor roster: ``n_sensors`` is the pool *capacity*, an
  unoccupied slot is simply one that is always fed ``None`` (all-zero
  carry, rides along as all-invalid padding at negligible vmap cost),
  :meth:`FleetPipeline.reset_slots` zeroes a slot's carries so a
  departing sensor's slot can be handed to a new one (an all-zero slot
  carry IS the fresh-stream initial state, so a recycled slot is
  bit-identical to a brand-new :class:`StreamingPipeline`), and
  :meth:`FleetPipeline.grow` migrates the carry into a larger pool
  (zero-padded along the sensor dim, re-sharded). Because the step's
  compiled shape depends only on the pool capacity — never on which
  slots are occupied — attach/detach churn compiles nothing; only a
  capacity-tier promotion (:func:`tier_capacity`) does, at most once
  per tier. The session/service layer on top lives in
  :mod:`repro.serve` (DESIGN.md Sec. 11).
"""
from __future__ import annotations

import contextlib
import copy
import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.events import (
    SPILL_QUANTUM,
    SPILL_SENTINEL,
    EventBatch,
    WindowedEvents,
    dense_wire_bytes,
    dual_threshold_bounds,
    dual_threshold_closed_bounds,
    monotone_merge,
    pack_bounds_into,
    ragged_wire_bytes,
    spill_pad,
    unpack_wire,
    wire_pad,
)
from repro.core.grid_clustering import Clusters
from repro.core.pipeline.config import PipelineConfig
from repro.core.pipeline.scan import ScanResult, _make_core, atlas_shape
from repro.core.pipeline.stream import empty_scan_result, tag_limit
from repro.core.tracking import TrackState, init_tracks
from repro.distributed.sharding import (
    grow_fleet_carry,
    hint_fleet,
    hint_wire,
    shard_fleet_carry,
    shrink_fleet_carry,
)

_EMPTY = np.zeros(0, np.int64)
_EMPTY_CHUNK = (_EMPTY, _EMPTY, _EMPTY, _EMPTY)

# Slot-pool capacity tiers: a pool never grows by one — it is promoted to
# the next tier, so attach/detach churn triggers at most one fleet-step
# compile per tier instead of one per sensor-count (compile discipline is
# pinned by tests/test_serve_service.py). Past the last tier, capacity
# doubles.
DEFAULT_TIERS = (4, 8, 16, 32, 64)

# Test hook: one entry per fleet-step *trace* (== XLA compile), recording
# (S, W, capacity, uniform). Compiled-cache hits never run the traced
# Python, so appending inside the step body counts compiles exactly.
STEP_TRACES: list[tuple[int, int, int, bool]] = []


# Staging sets kept alive per packed-block shape: churny services visit a
# handful of (S, W, cap) shapes; beyond this the least recently used
# ring's buffers are dropped (they are plain numpy arrays — any round
# still in flight keeps its own device copies and bookkeeping copies).
_MAX_STAGING_SHAPES = 8


def tier_capacity(n: int, tiers: tuple[int, ...] = DEFAULT_TIERS) -> int:
    """Smallest tier capacity holding ``n`` slots (doubling past the end)."""
    if n < 1:
        raise ValueError(f"need at least one slot, got {n}")
    for cap in tiers:
        if n <= cap:
            return cap
    cap = tiers[-1]
    while cap < n:
        cap *= 2
    return cap


@dataclasses.dataclass
class SensorCursor:
    """Host-side per-sensor batcher cursor (the non-device slice of what
    used to be :class:`StreamState`)."""

    pending: tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]
    events_consumed: int = 0  # stream index of pending[0]
    next_tag: int = 0  # next atlas tag (epoch-local)
    last_t: int | None = None  # newest absorbed timestamp

    @property
    def pending_count(self) -> int:
        return len(self.pending[2])


@dataclasses.dataclass
class FleetState:
    """Batched streaming carry: one cursor per sensor on host, stacked
    (leading sensor dim) atlas + tracker carries on device."""

    cursors: list[SensorCursor]
    atlas: jax.Array  # (S, H+1, max(W, cap)) — donated by the step
    tracks: TrackState  # leaves (S, T)

    @property
    def n_sensors(self) -> int:
        return len(self.cursors)


@functools.lru_cache(maxsize=None)
def make_fleet_fn(config: PipelineConfig = PipelineConfig(), with_tracking: bool = True):
    """Jit'd fleet step: the single-sensor core vmapped over the sensor dim.

        (packed (4,S,W,cap) x/y/t/p, valid (S,W,cap), state (S,T),
         atlas (S,H+1,Wd), meta (2,S) tag0/n_valid) ->
            (final_state (S,T), clusters (S,W,K), mets (S,W,K),
             states (S,W,T), atlas_out)

    The event planes arrive as ONE packed int32 block (plus the bool
    validity mask and one (2, S) meta row): per-feed host->device
    transfers are the measurable per-round overhead on CPU, and packing
    turns seven dispatches into three; unpacking inside the jit is free.
    ``meta[1]`` (``n_valid``) is each sensor's real window count this
    feed — the returned carry is the per-window tracker state at window
    ``n_valid - 1`` (or the previous carry when a sensor closed
    nothing), so the padding coast past it never reaches the next feed.
    ``uniform`` (static) asserts every sensor closed exactly ``W``
    windows — the common co-observing round — so the carry is just the
    last per-window state and the ragged reselection gathers (a
    measurable slice of the per-feed critical path, ~0.5 ms on the
    2-core reference box) compile out entirely; host picks the variant
    per feed and both produce identical carries on uniform feeds.
    Tag-epoch rollover (atlas slice re-zeroed) happens host-side in
    :meth:`FleetPipeline._ingest` on the rare feeds that need it — doing
    it here would stream the whole stacked atlas through a select on
    EVERY feed, which costs more than the entire vmapped core on small
    feeds. The stacked atlas is donated; sensor-axis sharding hints keep
    the carry partitioned across devices when a mesh is active. Compiled
    once per (config, S, W, capacity); cached per config.
    """
    core = _make_core(config, with_tracking)
    vcore = jax.vmap(core)

    def step(packed, valid, state, atlas, meta, uniform):
        STEP_TRACES.append(
            (packed.shape[1], packed.shape[2], packed.shape[3], uniform)
        )
        stacked = EventBatch(packed[0], packed[1], packed[2], packed[3], valid)
        tag0, n_valid = meta[0], meta[1]
        atlas = hint_fleet(atlas)
        state = hint_fleet(state)
        stacked = hint_fleet(stacked)
        _, clusters, mets, states, atlas = vcore(stacked, state, atlas, tag0)
        if uniform:
            final = jax.tree.map(lambda per_w: per_w[:, -1], states)
        else:
            s_ix = jnp.arange(n_valid.shape[0])
            last = jnp.maximum(n_valid - 1, 0)
            final = jax.tree.map(
                lambda per_w, prev: jnp.where(
                    (n_valid > 0)[:, None], per_w[s_ix, last], prev
                ),
                states,
                state,
            )
        return final, clusters, mets, states, hint_fleet(atlas)

    return jax.jit(step, donate_argnums=(3,), static_argnums=(5,))


@functools.lru_cache(maxsize=None)
def make_wire_fn(capacity: int, use_kernels: bool):
    """Jit'd ragged-wire decoder: compressed wire -> dense step inputs.

        (words (N,) uint32, dt (N,) uint16, pol (N/32,) uint32,
         offsets (S, W+1) int32, spill (5, M) int32) ->
            (packed (4, S, W, cap) int32, valid (S, W, cap) bool)

    Deliberately a SEPARATE jit in front of the fleet step, not fused
    into it: the wire length N varies with occupancy (bucketed to
    ``WIRE_QUANTUM``), and folding it into the step's compile key would
    break the one-compile-per-capacity-tier discipline the service pins
    (tests/test_serve_service.py). The decoder's outputs have exactly
    the dense staging shapes/dtypes, so the step's compiled cache is
    shared between both wire modes; decoder compiles are cheap (a few
    elementwise ops + one gather) and bounded by the occupancy buckets.
    ``use_kernels`` routes the word unpack through the Pallas
    ``event_unpack`` kernel (interpret mode off TPU), mirroring the
    quantize/accum routing; the jnp shift/mask path is the default.
    Sensor-axis sharding hints keep the reconstructed planes partitioned
    like the rest of the carry when a mesh is active.
    """
    if use_kernels:
        from repro.kernels.ops import event_unpack_call  # lazy, like config
        unpack_impl = event_unpack_call
    else:
        unpack_impl = None

    def decode(words, dt16, pol, offsets, spill):
        packed, valid = unpack_wire(
            words, dt16, pol, offsets, spill, capacity, unpack_impl
        )
        packed, valid, _ = hint_wire(packed, valid, offsets)
        return packed, valid

    return jax.jit(decode)


@functools.lru_cache(maxsize=1)
def _pinned_host_sharding():
    """Pinned-host placement for wire staging, when the backend has one.

    On accelerator backends whose devices expose a ``pinned_host``
    memory space (TPU/GPU runtimes), host->device DMA from pinned pages
    avoids a driver-side bounce copy; the ragged dispatch routes its
    wire views through this placement first. CPU backends (host memory
    IS device memory) and runtimes without the memory space return
    ``None`` and the views ship as plain numpy — behaviour, and bits,
    are identical either way.
    """
    if jax.default_backend() == "cpu":
        return None
    try:
        dev = jax.devices()[0]
        kinds = {m.kind for m in dev.addressable_memories()}
        if "pinned_host" not in kinds:
            return None
        return jax.sharding.SingleDeviceSharding(dev, memory_kind="pinned_host")
    except Exception:  # pragma: no cover - runtime-dependent introspection
        return None


def _stage_wire(views: tuple) -> tuple:
    """Bounce the per-round wire views through pinned host memory when
    the backend supports it (see :func:`_pinned_host_sharding`)."""
    sharding = _pinned_host_sharding()
    if sharding is None:
        return views
    try:
        return tuple(jax.device_put(v, sharding) for v in views)
    except Exception:  # pragma: no cover - degrade to plain numpy inputs
        return views


@dataclasses.dataclass
class WireStats:
    """Host->device ingest transfer accounting, accumulated per round.

    ``wire_bytes`` counts what the active wire mode actually ships;
    ``dense_bytes`` is the dense-equivalent cost of the same rounds
    (identical, by construction, when ``wire="dense"``), so
    ``compression`` is the measured transfer reduction the ragged wire
    delivers at the workload's real occupancy.
    """

    rounds: int = 0
    events: int = 0  # real (valid) events shipped
    wire_bytes: int = 0
    dense_bytes: int = 0
    spilled: int = 0  # events that took the exact int32 spill lane

    @property
    def compression(self) -> float:
        """Dense-equivalent bytes over shipped bytes (>= 1 when winning)."""
        return self.dense_bytes / self.wire_bytes if self.wire_bytes else 0.0

    @property
    def wire_bytes_per_round(self) -> float:
        return self.wire_bytes / self.rounds if self.rounds else 0.0

    def add(self, other: "WireStats") -> None:
        self.rounds += other.rounds
        self.events += other.events
        self.wire_bytes += other.wire_bytes
        self.dense_bytes += other.dense_bytes
        self.spilled += other.spilled


@functools.lru_cache(maxsize=None)
def _zero_sensors_fn():
    """Jit'd atlas-slice zeroing for tag-epoch rollover (donated, so the
    common no-rollover feed path never touches the stacked atlas)."""
    return jax.jit(
        lambda atlas, reset: jnp.where(reset[:, None, None], 0, atlas),
        donate_argnums=(0,),
    )


@functools.lru_cache(maxsize=None)
def _zero_slots_fn():
    """Jit'd whole-slot zeroing (atlas slice + tracker slice) for slot
    recycling. The atlas is donated like the step's; the tracker carry is
    not — the previous feed handed those buffers to the caller as
    ``final_tracks`` and zeroing in place would corrupt that result."""

    def zero(atlas, tracks, reset):
        atlas = jnp.where(reset[:, None, None], 0, atlas)
        tracks = jax.tree.map(
            lambda a: jnp.where(
                reset.reshape((-1,) + (1,) * (a.ndim - 1)), jnp.zeros_like(a), a
            ),
            tracks,
        )
        return atlas, tracks

    return jax.jit(zero, donate_argnums=(0,))


@functools.lru_cache(maxsize=None)
def _set_slot_fn():
    """Jit'd single-slot carry overwrite (atlas slice + tracker slice)
    for importing a migrated slot. The atlas is donated, mirroring
    :func:`_zero_slots_fn`; the tracker carry is not (the previous feed
    handed those buffers to callers as ``final_tracks``)."""

    def set_(atlas, tracks, slot, atlas_row, tracks_row):
        atlas = atlas.at[slot].set(atlas_row)
        tracks = jax.tree.map(
            lambda a, r: a.at[slot].set(r), tracks, tracks_row
        )
        return atlas, tracks

    return jax.jit(set_, donate_argnums=(0,))


@dataclasses.dataclass
class SlotCarry:
    """One slot's complete streaming carry, detached from its pool.

    The portable unit of cross-shard session migration (DESIGN.md
    Sec. 15): the host cursor plus host copies of the slot's atlas slice
    and tracker slice. Because the per-sensor carry IS the entire stream
    state, exporting a slot from one :class:`FleetPipeline` and importing
    it into a free slot of another (same :class:`PipelineConfig`) resumes
    the stream bit-identically — regardless of either pool's capacity,
    mesh, or slot index.
    """

    cursor: SensorCursor
    atlas: np.ndarray  # (H+1, Wd) int32 — the slot's atlas slice
    tracks: Any  # TrackState pytree, leaves (T, ...) numpy

    @property
    def pending_count(self) -> int:
        return self.cursor.pending_count


@dataclasses.dataclass
class FleetResult:
    """Stacked outputs of one fleet feed; per-sensor views on demand.

    Leaves keep the (S, W_max, ...) stacked layout — the shape the next
    O(1)-dispatch consumer (fleet evaluation, device-side reducers)
    wants — and :meth:`sensor` materializes the trimmed per-sensor
    :class:`ScanResult` lazily, so a latency-critical feed loop is not
    billed for S x leaves slice dispatches it never reads.
    """

    n_windows: np.ndarray  # (S,) real windows closed this feed
    windows: list[WindowedEvents]  # per-sensor host bookkeeping (real windows)
    clusters: Clusters | None  # leaves (S, W_max, K); None when no window closed
    metrics: dict[str, jax.Array] | None
    tracks: TrackState | None  # leaves (S, W_max, T)
    final_tracks: TrackState | None  # leaves (S, T) — corrected carry
    _config: PipelineConfig
    _with_tracking: bool
    _carry_tracks: TrackState  # (S, T) carry after this feed (empty-feed path)
    _host: tuple | None = None  # numpy copy of the stacked leaves, on demand
    _hot_rows: dict | None = None  # slot -> row into the gathered host leaves

    @property
    def n_sensors(self) -> int:
        return len(self.windows)

    @property
    def total_windows(self) -> int:
        return int(self.n_windows.sum())

    def _host_view(self) -> tuple:
        """Stacked outputs pulled to host, once per feed.

        Materializing S per-sensor results by slicing device arrays costs
        S x leaves tiny dispatches — measured ~5x the whole vmapped step
        on an 8-slot CPU fleet. One ``np.asarray`` per stacked leaf (a
        single transfer each, amortized over every sensor) makes each
        ``sensor(s)`` call pure numpy views. Values are the same bits, so
        the bit-identity contract is untouched; the device-resident
        stacked attributes stay as they were for O(1)-dispatch consumers.

        When most slots closed no window this feed — a sparsely occupied
        slot pool, the steady churny-service shape — copying the full
        (S, W, ...) leaves bills every padding row. Instead the hot rows
        (``n_windows > 0``) are gathered device-side (one fused take per
        leaf) and only those cross to host; ``sensor(s)`` maps its slot
        through ``_hot_rows``. A slot with zero windows trims ``[:0]``
        from row 0, which yields the same empty arrays the full copy
        would. ``final_tracks`` is every slot's carry — idle slots
        included — so it always crosses in full.
        """
        if self._host is None:
            s_count = len(self.windows)
            hot = np.flatnonzero(np.asarray(self.n_windows) > 0)
            if 2 * len(hot) >= s_count:
                # Mostly-hot fleet: plain per-leaf transfers beat the
                # extra gather dispatch per leaf.
                self._host = jax.tree.map(
                    np.asarray,
                    (self.clusters, self.metrics, self.tracks,
                     self.final_tracks),
                )
                self._hot_rows = None
            else:
                if jax.default_backend() == "cpu":
                    # Host memory IS device memory: np.asarray is a
                    # zero-copy view, so "transfer only the hot rows"
                    # means one numpy fancy-index per leaf (copies just
                    # those rows, and releases the full (S, W, ...)
                    # stacked buffers a long-held view would pin). A
                    # device-side gather here would cost a dispatched
                    # computation per leaf — measured ~90x the full view
                    # in benchmarks/serve_latency.py.
                    gather = lambda a: np.asarray(a)[hot]
                else:
                    # Separate device memory: gather on device so only
                    # the valid-window rows cross the wire.
                    idx = jnp.asarray(hot)
                    gather = lambda a: np.asarray(a[idx])
                self._host = (
                    jax.tree.map(gather, self.clusters),
                    jax.tree.map(gather, self.metrics),
                    jax.tree.map(gather, self.tracks),
                    jax.tree.map(np.asarray, self.final_tracks),
                )
                self._hot_rows = {int(s): i for i, s in enumerate(hot)}
        return self._host

    def ready(self) -> bool:
        """True when the device step behind this feed has completed (its
        output buffers are materialized). Host views never block once
        this holds."""
        if self.clusters is None:
            return True
        return all(
            getattr(leaf, "is_ready", lambda: True)()
            for leaf in jax.tree.leaves(
                (self.clusters, self.metrics, self.tracks, self.final_tracks)
            )
        )

    def block_until_ready(self) -> "FleetResult":
        if self.clusters is not None:
            jax.block_until_ready(
                (self.clusters, self.metrics, self.tracks, self.final_tracks)
            )
        return self

    def sensor(self, s: int) -> ScanResult:
        """Trimmed per-sensor result, bit-identical to the equivalent
        ``StreamingPipeline.feed`` return."""
        n = int(self.n_windows[s])
        w = self.windows[s]
        if self.clusters is None:
            carry_s = jax.tree.map(lambda a: a[s], self._carry_tracks)
            return empty_scan_result(self._config, self._with_tracking, carry_s, w)
        clusters_h, mets_h, tracks_h, final_h = self._host_view()
        row = s if self._hot_rows is None else self._hot_rows.get(s, 0)
        trim = lambda a: a[row, :n]
        clusters = jax.tree.map(trim, clusters_h)
        mets = {k: trim(v) for k, v in mets_h.items()}
        return ScanResult(
            t_start_us=w.t_start_us,
            clusters=clusters,
            metrics=mets,
            tracks=jax.tree.map(trim, tracks_h) if self._with_tracking else None,
            final_tracks=(
                jax.tree.map(lambda a: a[s], final_h)
                if self._with_tracking
                else None
            ),
            windows=w,
        )

    def results(self) -> list[ScanResult]:
        return [self.sensor(s) for s in range(self.n_sensors)]


@dataclasses.dataclass
class PendingRound:
    """Handle to one dispatched (possibly still executing) fleet round.

    :meth:`FleetPipeline.feed_async` dispatches the jitted step and
    returns immediately — JAX async dispatch means the returned arrays
    are futures. The handle makes the pipeline explicit: :meth:`ready`
    polls the device without blocking, :meth:`wait` synchronizes, and
    :meth:`result` hands back the :class:`FleetResult` without forcing
    either (its host views synchronize lazily at first consumption, so N
    in-flight rounds consumed together cost one sync, not N).
    """

    _result: FleetResult

    def ready(self) -> bool:
        """Poll: has the device step behind this round completed?"""
        return self._result.ready()

    def wait(self) -> FleetResult:
        """Block until the round's device buffers are materialized."""
        return self._result.block_until_ready()

    def result(self) -> FleetResult:
        """The round's result; does not block (host views are lazy)."""
        return self._result

    @property
    def n_windows(self) -> np.ndarray:
        """(S,) windows closed this round — host data, never blocks."""
        return self._result.n_windows

    @property
    def total_windows(self) -> int:
        return self._result.total_windows


class _StagingSet:
    """One preallocated host-side staging buffer set for a packed-block
    shape: the (4, S, W, cap) event planes, the (S, W, cap) validity
    mask, and the (2, S) tag/n_valid meta rows. ``inflight`` is the
    round currently borrowing the buffers (its transfer must complete —
    gated on the round's *outputs*, see :class:`_StagingPool` — before
    they are refilled)."""

    __slots__ = ("packed", "valid", "meta", "inflight")

    def __init__(self, s: int, w: int, cap: int):
        self.packed = np.zeros((4, s, w, cap), np.int32)
        self.valid = np.zeros((s, w, cap), bool)
        self.meta = np.zeros((2, s), np.int32)
        self.inflight: PendingRound | None = None


class _RaggedStagingSet:
    """Staging buffers for the compressed ragged wire (DESIGN.md Sec. 16):
    1-D word/delta/polarity lanes sized for the worst case (every slot of
    every window full), the CSR offsets block, and a growable spill lane.
    Unlike the dense set, acquire never zero-fills these: every round
    rewrites each sensor's full offsets row and the decoder's masked
    gather makes stale bytes past the round's event total unobservable
    (see ``unpack_wire``); only the spill view is re-sentineled per round
    — a stale spill entry WOULD scatter into live wire positions."""

    __slots__ = (
        "words", "dt", "pbits", "pol", "offsets", "spill", "meta", "inflight"
    )

    def __init__(self, s: int, w: int, cap: int):
        n_max = wire_pad(s * w * cap)
        self.words = np.zeros(n_max, np.uint32)
        self.dt = np.zeros(n_max, np.uint16)
        self.pbits = np.zeros(n_max, np.uint8)  # packbits scratch
        self.pol = np.zeros(n_max // 32, np.uint32)
        self.offsets = np.zeros((s, w + 1), np.int32)
        self.spill = np.full((5, 4 * SPILL_QUANTUM), SPILL_SENTINEL, np.int32)
        self.inflight: PendingRound | None = None
        self.meta = np.zeros((2, s), np.int32)

    def reserve_spill(self, m_pad: int) -> None:
        """Grow the spill lane to hold ``m_pad`` entries (amortized)."""
        if m_pad > self.spill.shape[1]:
            grown = spill_pad(max(m_pad, 2 * self.spill.shape[1]))
            self.spill = np.full((5, grown), SPILL_SENTINEL, np.int32)


class _StagingPool:
    """Depth-deep ring of reusable staging sets per packed-block shape.

    Double buffering (``depth=2``) lets round N+1 pack on host while
    round N computes on device: the two rounds use disjoint buffer sets,
    and acquiring a set whose borrower is still executing blocks until
    that round's outputs are ready. Outputs-ready is the conservative
    reuse gate — the step cannot have finished without having consumed
    its inputs, so refilling the numpy planes can never race the
    host->device transfer even if the runtime aliased them. Rings are
    kept per shape with LRU eviction past ``_MAX_STAGING_SHAPES``.
    """

    def __init__(self, depth: int = 2):
        if depth < 1:
            raise ValueError(f"staging depth must be >= 1, got {depth}")
        self.depth = depth
        # (s, w, cap, wire) -> [ix, sets]
        self._rings: dict[tuple[int, int, int, str], list] = {}

    def acquire(self, s: int, w: int, cap: int, wire: str = "dense"):
        key = (s, w, cap, wire)
        ring = self._rings.pop(key, None)
        if ring is None:
            cls = _RaggedStagingSet if wire == "ragged" else _StagingSet
            ring = [0, [cls(s, w, cap) for _ in range(self.depth)]]
        self._rings[key] = ring  # reinsert: dict order is the LRU order
        while len(self._rings) > _MAX_STAGING_SHAPES:
            self._rings.pop(next(iter(self._rings)))
        ix, sets = ring
        ring[0] = (ix + 1) % self.depth
        st = sets[ix]
        if st.inflight is not None:
            st.inflight.wait()
            st.inflight = None
        if wire == "dense":
            st.packed.fill(0)
            st.valid.fill(0)
        return st


class FleetPipeline:
    """Batched multi-sensor streaming driver (one step for the whole fleet).

    >>> fp = FleetPipeline(PipelineConfig(), n_sensors=8)
    >>> out = fp.feed([(x0, y0, t0, p0), None, (x2, y2, t2, p2), ...])
    >>> out.sensor(0).clusters  # == the equivalent StreamingPipeline feed
    >>> tail = fp.flush()       # close every sensor's trailing window

    ``feed`` takes one optional ``(x, y, t, p)`` chunk per sensor
    (``None`` = idle this feed) and runs ONE ``jit(vmap(core))`` step
    over every window that provably closed, fleet-wide. Passing
    ``mesh=`` (a mesh with a ``sensor`` axis) shards the carry and the
    step across devices. A chunk with out-of-order timestamps — within
    the chunk or against the sensor's stream — raises ``ValueError``
    before ANY sensor's state changes, as does a feed closing more
    windows than one tag epoch can address; the fleet stays usable and
    the same chunks can be re-fed.

    As a slot pool (see module docstring): ``n_sensors`` is the pool
    capacity, :meth:`reset_slots` zeroes departing slots for reuse,
    :meth:`grow` promotes the pool to a larger capacity with carry
    migration, and ``feed``'s ``final`` argument accepts a per-slot
    mask so one sensor's trailing window can be force-closed (sensor
    detach) without flushing the rest of the fleet.
    ``uniform_fast_path=False`` disables the static all-sensors-uniform
    step variant — dynamic-membership callers (the detection service)
    use it to pin compiles to exactly one step shape per (capacity,
    window-count) instead of two.

    ``feed`` dispatches asynchronously (the returned result's host views
    synchronize lazily); :meth:`feed_async` exposes the same round as an
    explicit :class:`PendingRound` handle so a pipelined caller can keep
    several rounds in flight and poll/await them. Host packing writes
    into ``staging_depth`` preallocated staging buffer sets per packed
    shape (double buffering by default) instead of allocating per round;
    a set is refilled only after the round borrowing it has completed.

    ``wire`` selects the host->device ingest format (DESIGN.md Sec. 16):
    ``"ragged"`` (the default) ships the compressed event wire — packed
    coordinate words, 16-bit window-relative deltas, a polarity
    bitplane, CSR offsets, and an exact spill lane — and reconstructs
    the dense staging planes in a separate jit'd decoder in front of the
    step, bit-identically; ``"dense"`` ships the (4, S, W, cap) planes
    directly. Both modes share the step's compiled cache; per-round
    transfer sizes accumulate in :attr:`wire_stats` either way.
    """

    def __init__(
        self,
        config: PipelineConfig = PipelineConfig(),
        n_sensors: int = 1,
        with_tracking: bool = True,
        mesh=None,
        state: FleetState | None = None,
        uniform_fast_path: bool = True,
        staging_depth: int = 2,
        wire: str = "ragged",
    ):
        if n_sensors < 1:
            raise ValueError(f"n_sensors must be >= 1, got {n_sensors}")
        if wire not in ("dense", "ragged"):
            raise ValueError(f"unknown wire mode: {wire!r}")
        self.config = config
        self.n_sensors = n_sensors
        self.with_tracking = with_tracking
        self.mesh = mesh
        self.uniform_fast_path = uniform_fast_path
        self.wire = wire
        self.wire_stats = WireStats()
        self._step = make_fleet_fn(config, with_tracking)
        self._wire = (
            make_wire_fn(config.batcher.capacity, config.use_kernels)
            if wire == "ragged"
            else None
        )
        self._tag_limit = tag_limit(config)
        self._staging = _StagingPool(staging_depth)
        self.state = self.init_state() if state is None else state
        if state is not None and state.n_sensors != n_sensors:
            raise ValueError(
                f"state has {state.n_sensors} sensors, pipeline expects {n_sensors}"
            )

    def init_state(self) -> FleetState:
        s = self.n_sensors
        atlas = jnp.zeros((s,) + atlas_shape(self.config), jnp.int32)
        tracks = jax.tree.map(
            lambda a: jnp.zeros((s,) + a.shape, a.dtype),
            init_tracks(self.config.tracker),
        )
        atlas, tracks = shard_fleet_carry((atlas, tracks), self.mesh)
        return FleetState(
            cursors=[SensorCursor(pending=_EMPTY_CHUNK) for _ in range(s)],
            atlas=atlas,
            tracks=tracks,
        )

    def _mesh_ctx(self):
        if self.mesh is None:
            return contextlib.nullcontext()
        from repro.launch.mesh import use_mesh  # one jax-compat shim, one home

        return use_mesh(self.mesh)

    def feed(self, chunks, final=False) -> FleetResult:
        """Ingest one chunk per sensor; process every closed window in one
        vmapped step. ``chunks[s]`` is ``(x, y, t, p)`` or ``None``.

        ``final`` may be a bool (flush every sensor's trailing partial
        window, as :meth:`flush` does) or a per-sensor boolean mask —
        masked slots are force-closed this feed (sensor detach) while
        the rest keep batching normally.
        """
        return self._ingest(chunks, final=final).result()

    def feed_async(self, chunks, final=False) -> PendingRound:
        """:meth:`feed`, as an explicit pipelined round: the jitted step
        is dispatched without synchronizing and a :class:`PendingRound`
        handle is returned. Validation errors still raise here, at the
        dispatch boundary, before any state mutation — a raised feed
        leaves the fleet untouched and re-feedable, exactly like the
        synchronous path. Rounds complete in dispatch order (one device
        stream), so interleaving ``feed_async`` with ``reset_slots`` /
        ``grow`` / ``shrink`` is safe: an earlier round's outputs are
        never perturbed by later carry surgery (outputs are not donated).
        """
        return self._ingest(chunks, final=final)

    def flush(self) -> FleetResult:
        """Force-close every sensor's trailing partial window."""
        return self._ingest([None] * self.n_sensors, final=True).result()

    def flush_slots(self, slots) -> FleetResult:
        """Force-close the trailing partial window of ``slots`` only."""
        final = np.zeros(self.n_sensors, bool)
        final[list(slots)] = True
        return self._ingest([None] * self.n_sensors, final=final).result()

    def reset_slots(self, slots) -> None:
        """Zero the named slots' carries (cursor + atlas slice + tracker
        slice) so they can be recycled by new sensors.

        An all-zero slot carry is exactly the fresh-stream initial state
        (``init_tracks`` is all zeros; a zero atlas is all-stale), so a
        recycled slot behaves bit-identically to a brand-new
        :class:`~repro.core.pipeline.stream.StreamingPipeline`. Any
        unflushed remainder on the slot is dropped — flush first
        (:meth:`flush_slots`) if the trailing window matters.
        """
        slots = list(slots)
        if not slots:
            return
        mask = np.zeros(self.n_sensors, bool)
        mask[slots] = True  # IndexError on out-of-range slots, pre-mutation
        st = self.state
        for s in np.flatnonzero(mask):
            st.cursors[s] = SensorCursor(pending=_EMPTY_CHUNK)
        with self._mesh_ctx():
            atlas, tracks = _zero_slots_fn()(st.atlas, st.tracks, jnp.asarray(mask))
        self.state = FleetState(cursors=st.cursors, atlas=atlas, tracks=tracks)

    def export_slot(self, slot: int) -> SlotCarry:
        """Copy one slot's complete carry out of the pool (host arrays).

        The returned :class:`SlotCarry` is self-contained: the host
        cursor (with its unwindowed remainder) plus host copies of the
        slot's atlas and tracker slices. Forces the slices to host, so
        it blocks until any round still computing this slot's carry has
        completed (rounds never run concurrently with carry surgery on
        the same buffers anyway — outputs are not donated). The slot
        itself is left untouched; callers recycling it afterwards use
        :meth:`reset_slots`, exactly like a detach.
        """
        if not 0 <= slot < self.n_sensors:
            raise IndexError(
                f"slot {slot} out of range for a {self.n_sensors}-slot pool"
            )
        st = self.state
        return SlotCarry(
            cursor=copy.copy(st.cursors[slot]),
            # Slicing materializes a fresh device buffer, so the host
            # copy can never alias a donated carry buffer.
            atlas=np.asarray(st.atlas[slot]),
            tracks=jax.tree.map(lambda a: np.asarray(a[slot]), st.tracks),
        )

    def import_slot(self, slot: int, carry: SlotCarry) -> None:
        """Install an exported carry into ``slot`` (cross-shard adopt).

        The target slot's previous carry is overwritten — callers hand
        in a free (reset) slot. Shapes are validated against this pool's
        config before any mutation, so a carry exported under a
        different :class:`PipelineConfig` is refused atomically. The
        new carry is written under the pool's mesh, so it lands sharded
        over the ``sensor`` axis like every other slot.
        """
        if not 0 <= slot < self.n_sensors:
            raise IndexError(
                f"slot {slot} out of range for a {self.n_sensors}-slot pool"
            )
        want = atlas_shape(self.config)
        if tuple(carry.atlas.shape) != want:
            raise ValueError(
                f"carry atlas shape {carry.atlas.shape} does not match this "
                f"pool's config ({want}); same PipelineConfig required"
            )
        st = self.state
        ref = jax.tree.map(lambda a: a.shape[1:], st.tracks)
        got = jax.tree.map(lambda a: tuple(a.shape), carry.tracks)
        if jax.tree.leaves(ref) != jax.tree.leaves(got):
            raise ValueError(
                f"carry tracker shapes {jax.tree.leaves(got)} do not match "
                f"this pool's ({jax.tree.leaves(ref)})"
            )
        st.cursors[slot] = copy.copy(carry.cursor)
        with self._mesh_ctx():
            atlas, tracks = _set_slot_fn()(
                st.atlas,
                st.tracks,
                jnp.asarray(slot, jnp.int32),
                jnp.asarray(carry.atlas),
                jax.tree.map(jnp.asarray, carry.tracks),
            )
        self.state = FleetState(cursors=st.cursors, atlas=atlas, tracks=tracks)

    def grow(self, new_capacity: int) -> None:
        """Promote the pool to ``new_capacity`` slots, migrating the carry.

        Existing slots keep their state verbatim (zero-padding along the
        leading sensor dim cannot perturb them — the step is vmapped, so
        sensors never mix); new slots arrive zeroed, i.e. free. The
        carry is re-placed under the mesh so slot-pool carries keep
        sharding over the ``sensor`` axis after promotion. Compiles
        nothing by itself; the next feed compiles the step at the new
        capacity (once per capacity, the tier-promotion budget).
        """
        if new_capacity < self.n_sensors:
            raise ValueError(
                f"cannot shrink pool from {self.n_sensors} to {new_capacity} "
                "slots; detach sensors instead"
            )
        if new_capacity == self.n_sensors:
            return
        st = self.state
        atlas, tracks = grow_fleet_carry(
            (st.atlas, st.tracks), new_capacity, self.mesh
        )
        cursors = st.cursors + [
            SensorCursor(pending=_EMPTY_CHUNK)
            for _ in range(new_capacity - len(st.cursors))
        ]
        self.n_sensors = new_capacity
        self.state = FleetState(cursors=cursors, atlas=atlas, tracks=tracks)

    def shrink(self, new_capacity: int, occupied=()) -> None:
        """Demote the pool to ``new_capacity`` slots, migrating the carry.

        The inverse of :meth:`grow`, for reclaiming capacity after
        evictions: the dropped tail slots must all be free (every slot in
        ``occupied`` must be ``< new_capacity``), so surviving slots keep
        their state verbatim — slicing the leading sensor dim cannot
        perturb them, exactly as zero-padding cannot in :meth:`grow`.
        Any unflushed remainder on a dropped slot is discarded (callers
        flush or reset departing slots first). Compiles nothing by
        itself; the next feed compiles the step at the new capacity,
        which is a shape already warmed if this tier was visited on the
        way up.
        """
        if new_capacity < 1:
            raise ValueError(f"need at least one slot, got {new_capacity}")
        if new_capacity > self.n_sensors:
            raise ValueError(
                f"cannot shrink pool from {self.n_sensors} to {new_capacity} "
                "slots; use grow"
            )
        high = [s for s in occupied if s >= new_capacity]
        if high:
            raise ValueError(
                f"occupied slots {sorted(high)} do not fit a "
                f"{new_capacity}-slot pool; migrate or evict them first"
            )
        if new_capacity == self.n_sensors:
            return
        st = self.state
        atlas, tracks = shrink_fleet_carry(
            (st.atlas, st.tracks), new_capacity, self.mesh
        )
        self.n_sensors = new_capacity
        self.state = FleetState(
            cursors=st.cursors[:new_capacity], atlas=atlas, tracks=tracks
        )

    def _ingest(self, chunks, final) -> PendingRound:
        st = self.state
        s_count = st.n_sensors
        if len(chunks) != s_count:
            raise ValueError(
                f"feed expects {s_count} per-sensor chunks, got {len(chunks)}"
            )
        if isinstance(final, bool):
            final = np.full(s_count, final, bool)
        else:
            final = np.asarray(final, bool)
            if final.shape != (s_count,):
                raise ValueError(
                    f"final mask must have shape ({s_count},), got {final.shape}"
                )
        batcher = self.config.batcher
        merged_all, bounds_all, consumed_all = [], [], []
        # Phase A (fallible): validate + window every sensor BEFORE any
        # state mutation, so a bad chunk rejects the whole feed atomically.
        for s, (cur, chunk) in enumerate(zip(st.cursors, chunks)):
            x, y, t, p = _EMPTY_CHUNK if chunk is None else chunk
            merged = monotone_merge(
                cur.pending, x, y, t, p, cur.last_t, label=f"sensor {s}"
            )
            if final[s]:
                bounds = dual_threshold_bounds(merged[2], batcher)
                consumed = len(merged[2])
            else:
                bounds, consumed = dual_threshold_closed_bounds(merged[2], batcher)
            merged_all.append(merged)
            bounds_all.append(bounds)
            consumed_all.append(consumed)
        n_valid = np.asarray([len(b) for b in bounds_all], np.int32)
        w_max = int(n_valid.max())
        if w_max > self._tag_limit:
            raise ValueError(
                f"feed closed {w_max} windows on one sensor, more than one "
                f"tag epoch ({self._tag_limit}) can address; split the feed"
            )

        # Phase B (infallible): pack all sensors into one (4, S, W_max,
        # cap) x/y/t/p block (single host->device transfer), resolve
        # tags/rollover, commit cursors. The block lives in a reusable
        # staging set (acquire blocks iff the set's previous borrower is
        # still executing — the pipelined-depth backpressure point), so
        # the steady state allocates nothing per round.
        cap = batcher.capacity
        ragged = self.wire == "ragged"
        staging = (
            self._staging.acquire(s_count, w_max, cap, wire=self.wire)
            if w_max
            else None
        )
        if staging is None or ragged:
            bx = by = bt = bp = bv = None
        else:
            bx, by, bt, bp = staging.packed
            bv = staging.valid
        wire_base = 0  # running write cursor into the shared wire lanes
        spill_blocks: list[np.ndarray] = []
        events_total = 0
        tag0 = np.zeros(s_count, np.int32)
        reset = np.zeros(s_count, bool)
        windows_list: list[WindowedEvents] = []
        for s, (cur, merged, bounds, consumed) in enumerate(
            zip(st.cursors, merged_all, bounds_all, consumed_all)
        ):
            mt = merged[2]
            bounds3 = [(a, b, int(mt[a])) for a, b in bounds]
            if staging is None:
                starts = stops = t_start = np.zeros(0, np.int64)
                overflow = np.zeros(0, np.int64)
                zeros = np.zeros((0, cap), np.int32)
                row = EventBatch(
                    zeros, zeros, zeros, zeros, np.zeros((0, cap), bool)
                )
            elif ragged:
                starts, stops, t_start, overflow, wire_base, entries = (
                    pack_bounds_into(
                        *merged, bounds3,
                        out=(staging.words, staging.dt, staging.pbits,
                             staging.offsets[s]),
                        layout="ragged", base=wire_base, capacity=cap,
                    )
                )
                if entries.shape[1]:
                    spill_blocks.append(entries)
                n = len(bounds)
                # Bookkeeping rows are fresh dense planes (the ragged
                # wire has no per-window rows to copy out): same packer,
                # same bits, and like the dense path's copies they stay
                # stable for the round's lifetime.
                rx = np.zeros((n, cap), np.int32)
                ry = np.zeros((n, cap), np.int32)
                rt = np.zeros((n, cap), np.int32)
                rp = np.zeros((n, cap), np.int32)
                rv = np.zeros((n, cap), bool)
                if n:
                    pack_bounds_into(*merged, bounds3, rx, ry, rt, rp, rv)
                row = EventBatch(rx, ry, rt, rp, rv)
            else:
                starts, stops, t_start, overflow = pack_bounds_into(
                    *merged, bounds3, out=(bx[s], by[s], bt[s], bp[s], bv[s])
                )
                n = len(bounds)
                # Per-sensor bookkeeping rows are COPIES of the packed
                # rows, not views: the staging planes are refilled two
                # rounds later, but the WindowedEvents a caller holds
                # must stay stable for the round's lifetime.
                row = EventBatch(
                    bx[s, :n].copy(), by[s, :n].copy(), bt[s, :n].copy(),
                    bp[s, :n].copy(), bv[s, :n].copy(),
                )
            events_total += int(np.minimum(stops - starts, cap).sum())
            n = len(bounds)
            base = cur.events_consumed
            windows_list.append(
                WindowedEvents(
                    row, t_start, starts + base, stops + base, overflow
                )
            )
            t0 = cur.next_tag
            if t0 + w_max > self._tag_limit:  # tag epoch rollover
                reset[s], t0 = True, 0
            tag0[s] = t0
            cur.pending = tuple(a[consumed:] for a in merged)
            cur.events_consumed = base + consumed
            cur.next_tag = t0 + n
            cur.last_t = int(mt[-1]) if len(mt) else cur.last_t

        if w_max == 0:
            return PendingRound(FleetResult(
                n_windows=n_valid,
                windows=windows_list,
                clusters=None, metrics=None, tracks=None, final_tracks=None,
                _config=self.config,
                _with_tracking=self.with_tracking,
                _carry_tracks=st.tracks,
            ))

        staging.meta[0] = tag0
        staging.meta[1] = n_valid
        if ragged:
            n_pad = wire_pad(wire_base)
            m = 0
            if spill_blocks:
                entries = np.concatenate(spill_blocks, axis=1)
                m = entries.shape[1]
            m_pad = spill_pad(m)
            staging.reserve_spill(m_pad)
            # Re-sentinel the whole view EVERY round: a stale spill entry
            # from a previous borrower points at live wire positions and
            # would overwrite real events in the decoder's scatter.
            staging.spill[:, :m_pad] = SPILL_SENTINEL
            if m:
                staging.spill[:, :m] = entries
                self.wire_stats.spilled += m
            if wire_base:
                packed_bits = np.packbits(
                    staging.pbits[:wire_base], bitorder="little"
                )
                staging.pol.view(np.uint8)[: len(packed_bits)] = packed_bits
            views = _stage_wire((
                staging.words[:n_pad], staging.dt[:n_pad],
                staging.pol[: n_pad // 32], staging.offsets,
                staging.spill[:, :m_pad],
            ))
            wire_b = ragged_wire_bytes(n_pad, s_count, w_max, m_pad)
        else:
            wire_b = dense_wire_bytes(s_count, w_max, cap)
        with self._mesh_ctx():
            atlas_in = st.atlas
            if reset.any():  # rare: tag-epoch rollover on some sensor(s)
                atlas_in = _zero_sensors_fn()(atlas_in, jnp.asarray(reset))
            if ragged:
                packed_in, valid_in = self._wire(*views)
            else:
                packed_in, valid_in = staging.packed, bv
            final_tracks, clusters, mets, states, atlas = self._step(
                packed_in, valid_in, st.tracks, atlas_in, staging.meta,
                self.uniform_fast_path and bool((n_valid == w_max).all()),
            )
        self.wire_stats.rounds += 1
        self.wire_stats.events += events_total
        self.wire_stats.wire_bytes += wire_b
        self.wire_stats.dense_bytes += dense_wire_bytes(s_count, w_max, cap)
        self.state = FleetState(
            cursors=st.cursors, atlas=atlas, tracks=final_tracks
        )
        pending = PendingRound(FleetResult(
            n_windows=n_valid,
            windows=windows_list,
            clusters=clusters,
            metrics=mets,
            tracks=states if self.with_tracking else None,
            final_tracks=final_tracks,
            _config=self.config,
            _with_tracking=self.with_tracking,
            _carry_tracks=final_tracks,
        ))
        staging.inflight = pending
        return pending
