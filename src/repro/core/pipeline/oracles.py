"""Host-side oracles for device-resident candidate collection.

:func:`collect_candidates_numpy` is the float64 numpy-vectorized
matcher; :func:`collect_candidates_loop` is the first-principles
per-window/per-cluster Python loop. Both are semantically identical to
``evaluate.collect_candidates`` and exist so the device path stays
testable against independent implementations.
"""
from __future__ import annotations

import numpy as np

from repro.core.events import dual_threshold_batches
from repro.core.pipeline.config import PipelineConfig
from repro.core.pipeline.evaluate import (
    Candidates,
    _floor_config,
    _visible_objects,
    track_positions,
    track_table,
)
from repro.core.pipeline.scan import run_recording_scan
from repro.core.pipeline.window_core import make_process_window

from typing import TYPE_CHECKING

if TYPE_CHECKING:  # avoid circular import (data.synthetic uses core.events)
    from repro.data.synthetic import Recording


def collect_candidates_numpy(
    recording: Recording,
    config: PipelineConfig = PipelineConfig(),
    candidate_floor: int = 2,
    max_samples: int | None = None,
    gate_px: float = 14.0,
    min_truth_events: int = 3,
) -> Candidates:
    """Numpy-vectorized truth matching over the stacked scan outputs.

    The host oracle for :func:`collect_candidates` (float64 matching, same
    ordering and bookkeeping); itself pinned against
    :func:`collect_candidates_loop`.
    """
    result = run_recording_scan(
        recording, _floor_config(config, candidate_floor), with_tracking=False
    )
    windows = result.windows

    counts = np.asarray(result.clusters.count)  # (W, K)
    valid = np.asarray(result.clusters.valid)
    cx = np.asarray(result.clusters.centroid_x, np.float64)
    cy = np.asarray(result.clusters.centroid_y, np.float64)
    ct = np.asarray(result.clusters.centroid_t, np.float64)
    w_count, k = counts.shape if counts.ndim == 2 else (0, 0)

    tracks = track_table(recording.rso_tracks)
    n_rso = tracks.shape[0]

    # Cluster-level: match every (window, slot) centroid against every RSO
    # trajectory at the cluster's mean event time.
    t_ev = windows.t_start_us[:, None].astype(np.float64) + ct  # (W, K)
    ts = t_ev[:, :, None] * 1e-6  # seconds, (W, K, 1)
    px, py = track_positions(tracks[None, None, :, :], ts)  # (W, K, R)
    matched = (
        np.hypot(px - cx[:, :, None], py - cy[:, :, None]) <= gate_px
    )  # (W, K, R)

    # Candidate ordering is window-major, slot order — same as the loop.
    flat_valid = valid.reshape(-1)
    if max_samples is None:
        keep_flat = flat_valid
    else:
        rank = np.cumsum(flat_valid) - 1
        keep_flat = flat_valid & (rank < max_samples)
    keep = keep_flat.reshape(w_count, k)
    counts_out = counts.reshape(-1)[keep_flat].astype(np.int32)
    is_rso = matched.any(axis=-1).reshape(-1)[keep_flat]

    visible = _visible_objects(recording, windows.stops, n_rso, min_truth_events)
    contrib = np.where(
        matched & keep[:, :, None], counts[:, :, None], 0
    )  # (W, K, R)
    best = contrib.max(axis=1) if k else np.zeros((w_count, n_rso), counts.dtype)
    object_best = best[visible]

    return Candidates(
        counts_out,
        np.asarray(is_rso, bool),
        np.asarray(object_best, np.int32),
    )


def collect_candidates_loop(
    recording: Recording,
    config: PipelineConfig = PipelineConfig(),
    candidate_floor: int = 2,
    max_samples: int | None = None,
    gate_px: float = 14.0,
    min_truth_events: int = 3,
) -> Candidates:
    """Legacy per-window/per-cluster Python loop (first-principles oracle).

    Semantically identical to :func:`collect_candidates`; kept so the
    vectorized paths stay testable against first-principles code.
    """
    from repro.data.synthetic import KIND_RSO

    floor_cfg = _floor_config(config, candidate_floor)
    process_window = make_process_window(floor_cfg)
    counts_out: list[int] = []
    truth_out: list[bool] = []
    object_best: list[int] = []
    n_rso = track_table(recording.rso_tracks).shape[0]

    for batch, sl in dual_threshold_batches(
        recording.x, recording.y, recording.t, recording.p, floor_cfg.batcher
    ):
        clusters, _ = process_window(batch)
        counts = np.asarray(clusters.count)
        valid = np.asarray(clusters.valid)
        cxs = np.asarray(clusters.centroid_x)
        cys = np.asarray(clusters.centroid_y)
        cts = np.asarray(clusters.centroid_t)
        t0 = float(recording.t[sl.start])
        # Object-level bookkeeping: best matched count per visible RSO.
        kinds = recording.kind[sl]
        objs = recording.obj[sl]
        best = {}
        for r in range(n_rso):
            n_true = int(np.sum((kinds == KIND_RSO) & (objs == r)))
            if n_true >= min_truth_events:
                best[r] = 0
        for k in range(len(counts)):
            if not valid[k]:
                continue
            if max_samples is not None and len(counts_out) >= max_samples:
                break
            cx, cy = float(cxs[k]), float(cys[k])
            t_ev = t0 + float(cts[k])
            matched = False
            for r in range(n_rso):
                px, py = recording.rso_position(r, np.array([t_ev]))
                if np.hypot(px[0] - cx, py[0] - cy) <= gate_px:
                    matched = True
                    if r in best:
                        best[r] = max(best[r], int(counts[k]))
            counts_out.append(int(counts[k]))
            truth_out.append(matched)
        object_best.extend(best.values())
    return Candidates(
        np.asarray(counts_out, np.int32),
        np.asarray(truth_out, bool),
        np.asarray(object_best, np.int32),
    )
