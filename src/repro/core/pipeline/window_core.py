"""The per-window stage shared by every driver, plus the host-loop driver.

``_window_core`` (conditioning -> clustering -> metrics) is the single
definition of "process one window"; the scan, stream, and loop drivers
all execute it — that shared core is what makes their bit-identity a
structural property rather than a coincidence.

``run_recording`` is the legacy host loop: dual-threshold batching with
one jit dispatch (and host sync) per window. With :func:`make_process_window`
memoized per config, repeated runs measure pure dispatch overhead — the
baseline the scanned and streaming drivers are judged against.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import TYPE_CHECKING, Callable

import jax
import numpy as np

from repro.core.events import EventBatch, dual_threshold_batches, roi_filter
from repro.core.events import persistent_event_filter
from repro.core.grid_clustering import Clusters, clusters_from_histogram, merge_adjacent
from repro.core.pipeline.config import PipelineConfig, _histogram_fn, _metrics_fn
from repro.core.tracking import TrackerConfig, TrackState, init_tracks, tracker_step

if TYPE_CHECKING:  # avoid circular import (data.synthetic uses core.events)
    from repro.data.synthetic import Recording


def _condition(config: PipelineConfig, batch: EventBatch) -> EventBatch:
    batch = roi_filter(batch, config.roi)
    return persistent_event_filter(batch, config.hot_pixel_max)


def _cluster(
    config: PipelineConfig, hist_fn: Callable[[EventBatch], tuple], batch: EventBatch
) -> Clusters:
    clusters = clusters_from_histogram(*hist_fn(batch), config.grid)
    if config.merge_neighbors:
        clusters = merge_adjacent(clusters, config.grid)
    return clusters


def _window_core(
    config: PipelineConfig,
    hist_fn: Callable[[EventBatch], tuple],
    metrics_fn: Callable[[EventBatch, Clusters], dict[str, jax.Array]],
    batch: EventBatch,
) -> tuple[Clusters, dict[str, jax.Array]]:
    """The per-window computation shared by the loop/scan/stream drivers."""
    batch = _condition(config, batch)
    clusters = _cluster(config, hist_fn, batch)
    mets = metrics_fn(batch, clusters)
    return clusters, mets


@functools.lru_cache(maxsize=None)
def make_process_window(config: PipelineConfig = PipelineConfig()):
    """Build the jit'd per-window stage: conditioning -> clusters -> metrics.

    Memoized per config (like :func:`repro.core.pipeline.make_scan_fn`), so
    callers that rebuild it per recording reuse the compiled closure
    instead of re-tracing — the loop driver's cost is per-window dispatch,
    not retracing.
    """
    if config.numerics == "fixed":
        from repro.core.fixed_point import make_fixed_process_window

        return make_fixed_process_window(config)
    if config.numerics != "float":
        raise ValueError(f"unknown numerics: {config.numerics!r}")
    hist_fn = _histogram_fn(config)
    metrics_fn = _metrics_fn(config)

    @jax.jit
    def process_window(batch: EventBatch) -> tuple[Clusters, dict[str, jax.Array]]:
        return _window_core(config, hist_fn, metrics_fn, batch)

    return process_window


@functools.lru_cache(maxsize=None)
def _tracker_fn(config: TrackerConfig):
    """Memoized jit'd tracker step (one compile per tracker config)."""
    return jax.jit(functools.partial(tracker_step, config=config))


@dataclasses.dataclass
class WindowResult:
    t_start_us: int
    clusters: Clusters  # device arrays, K slots
    metrics: dict[str, np.ndarray]
    tracks: TrackState | None = None


def run_recording(
    recording: Recording,
    config: PipelineConfig = PipelineConfig(),
    with_tracking: bool = True,
) -> list[WindowResult]:
    """Host driver: dual-threshold batching + jit'd window stage + tracker.

    One dispatch per window; see ``run_recording_scan`` for the
    device-resident path with one dispatch per recording, and
    ``StreamingPipeline`` for incremental chunked feeds.
    """
    process_window = make_process_window(config)
    tracker_fn = _tracker_fn(config.tracker)
    state = init_tracks(config.tracker)
    results: list[WindowResult] = []
    for batch, sl in dual_threshold_batches(
        recording.x, recording.y, recording.t, recording.p, config.batcher
    ):
        clusters, mets = process_window(batch)
        if with_tracking:
            state, _ = tracker_fn(state, clusters, mets["shannon_entropy"])
        results.append(
            WindowResult(
                t_start_us=int(recording.t[sl.start]),
                clusters=clusters,
                metrics={k: np.asarray(v) for k, v in mets.items()},
                tracks=state if with_tracking else None,
            )
        )
    return results
