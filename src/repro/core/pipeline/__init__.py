"""End-to-end RSO detection pipeline (paper Fig. 2), as a layered package.

Stages, matching the paper's data flow:

  event capture -> conditioning (ROI + persistent-event removal)
    -> spatial quantization        [FPGA IP core -> Pallas kernel / jnp]
    -> cluster formation           [client software -> scatter + top-k]
    -> min_events threshold + metrics
    -> tracking (spatial-coherence validation)

Layers (each also importable directly):

* ``config``      — :class:`PipelineConfig` + per-stage impl selectors.
* ``window_core`` — the per-window stage shared by every driver, and the
  legacy host-loop driver :func:`run_recording`.
* ``scan``        — the device-resident step core and the offline
  drivers :func:`run_recording_scan` / :func:`run_many_scan`.
* ``event_core``  — the phased event-space step core with the
  persistent tagged atlas (DESIGN.md Sec. 5).
* ``stream``      — :class:`StreamingPipeline`: resumable chunked feeds,
  bit-identical to the scan driver for any chunking.
* ``fleet``       — :class:`FleetPipeline`: N live sensors through one
  vmapped/jitted step with sensor-sharded stacked carries,
  bit-identical per sensor to N independent streaming pipelines.
* ``evaluate``    — device-resident candidate truth-matching, scoring,
  and the O(1)-dispatch :func:`threshold_sweep`.
* ``oracles``     — host-side (numpy / Python-loop) matching oracles.

This module re-exports the full public API, so
``from repro.core.pipeline import run_recording_scan`` keeps working as
it did when the pipeline was a single module.
"""
from repro.core.pipeline.config import (  # noqa: F401
    PipelineConfig,
    _histogram_fn,
    _metrics_fn,
)
from repro.core.pipeline.window_core import (  # noqa: F401
    WindowResult,
    _cluster,
    _condition,
    _tracker_fn,
    _window_core,
    make_process_window,
    run_recording,
)
from repro.core.pipeline.scan import (  # noqa: F401
    ScanResult,
    make_atlas,
    make_scan_fn,
    make_stream_fn,
    run_many_scan,
    run_recording_scan,
)
from repro.core.pipeline.stream import (  # noqa: F401
    StreamState,
    StreamingPipeline,
    empty_scan_result,
    tag_limit,
)
from repro.core.pipeline.fleet import (  # noqa: F401
    DEFAULT_TIERS,
    FleetPipeline,
    FleetResult,
    FleetState,
    PendingRound,
    SensorCursor,
    make_fleet_fn,
    tier_capacity,
)
from repro.core.pipeline.evaluate import (  # noqa: F401
    Candidates,
    DetectionScore,
    collect_candidates,
    collect_candidates_fleet,
    collect_candidates_many,
    evaluate_detection,
    merge_candidates,
    score_threshold,
    threshold_sweep,
    track_positions,
    track_table,
)
from repro.core.pipeline.oracles import (  # noqa: F401
    collect_candidates_loop,
    collect_candidates_numpy,
)
# Tracker entry points have always been reachable via this module; keep
# that surface for drivers and benchmarks.
from repro.core.tracking import init_tracks, tracker_step  # noqa: F401
