"""Pipeline configuration + per-stage implementation selectors.

This is the one place the pipeline's tuning knobs are documented
(README and DESIGN.md point here):

* ``metrics_impl`` — which implementation computes the six per-cluster
  quality metrics (paper Sec. III-E). All three produce bit-identical
  values (pinned by ``tests/test_event_metrics.py``):

  - ``"event"`` (default): frame-free event-space path, O(E + K *
    patch^2) per window. Inside the scan/stream drivers it additionally
    uses the persistent window-tagged event atlas (DESIGN.md Sec. 5).
  - ``"frame"``: the paper's original data flow — sensor-sized
    accumulation image, global-max normalizer, patch slicing. O(sensor
    area) per window; kept as the bit-exactness oracle.
  - ``"kernel"``: the fused Pallas ``patch_metrics`` kernel
    (interpret-mode on CPU, compiled on TPU).

* ``scan_chunk`` — window-block size for the event-space driver's
  batched conditioning/clustering/stats phases (DESIGN.md Sec. 5). A
  cache-locality / vector-width scheduling knob only: results are
  invariant to it, including across the streaming engine's feed
  boundaries.

* ``use_kernels`` — route spatial quantization + cluster accumulation
  through the Pallas ``cluster_accum`` kernel instead of the jnp
  scatter (bit-identical; exercised by ``tests/test_pipeline_scan.py``).

* ``numerics`` — arithmetic datapath for the per-window stage chain:

  - ``"float"`` (default): the float32 golden model described above.
  - ``"fixed"``: the hardware-faithful integer datapath
    (``repro.core.fixed_point``) — int32 accumulators everywhere, float
    only in the per-cluster scalar epilogue, mirroring the paper's
    fixed-point fabric. Detection scores are bit-identical to the float
    path where DESIGN.md Sec. 12 claims so, and within documented
    bounds elsewhere. Under ``numerics="fixed"``, ``metrics_impl``
    selects ``"event"``/``"staged"`` (staged integer jnp stages, the
    golden reference) or ``"megakernel"`` (the fused Pallas
    ``window_pipeline`` kernel: one launch per window batch,
    bit-identical to the staged fixed path); ``"frame"``/``"kernel"``,
    ``use_kernels`` and ``merge_neighbors`` are float-path-only and
    raise ``ValueError``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax

from repro.core import metrics as M
from repro.core.events import DEFAULT_ROI, BatcherConfig, EventBatch
from repro.core.grid_clustering import Clusters, GridConfig, cell_histogram
from repro.core.tracking import TrackerConfig


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    grid: GridConfig = GridConfig()
    batcher: BatcherConfig = BatcherConfig()
    tracker: TrackerConfig = TrackerConfig()
    roi: tuple[int, int, int, int] = DEFAULT_ROI
    hot_pixel_max: int = 12
    merge_neighbors: bool = False
    use_kernels: bool = False  # route quantize+accumulate through Pallas
    metrics_impl: str = "event"  # "event" | "frame" | "kernel" (see module doc)
    scan_chunk: int = 8  # event-scan phase block size (scheduling only)
    numerics: str = "float"  # "float" | "fixed" (see module doc)


def _histogram_fn(config: PipelineConfig) -> Callable[[EventBatch], tuple]:
    if config.use_kernels:
        # Imported lazily: kernels are optional at pipeline import time.
        from repro.kernels import ops as kops

        def fn(batch: EventBatch):
            # Trace-time call (no nested jit): shapes are static inside
            # both the per-window jit and the scan body.
            return kops.cluster_accum_call(
                batch.x, batch.y, batch.t, batch.valid,
                cell_size=config.grid.cell_size,
                grid_w=config.grid.grid_w,
                grid_h=config.grid.grid_h,
                width=config.grid.width,
                height=config.grid.height,
            )

        return fn
    return lambda batch: cell_histogram(batch, config.grid)


def _metrics_fn(
    config: PipelineConfig,
) -> Callable[[EventBatch, Clusters], dict[str, jax.Array]]:
    """Per-window metrics stage for the configured implementation."""
    impl = config.metrics_impl
    w, h = config.grid.width, config.grid.height
    if impl == "frame":
        return lambda batch, clusters: M.cluster_metrics_frame(batch, clusters, w, h)
    if impl == "event":
        return lambda batch, clusters: M.cluster_metrics_events(batch, clusters, w, h)
    if impl == "kernel":
        from repro.kernels import ops as kops

        return lambda batch, clusters: kops.patch_metrics_call(
            batch, clusters, width=w, height=h
        )
    raise ValueError(f"unknown metrics_impl: {impl!r}")
