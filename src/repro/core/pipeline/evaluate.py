"""Accuracy evaluation (paper Sec. V-A: sampled detections vs ground truth).

Candidate truth-matching runs on device: a jit'd matcher evaluates every
(window, cluster slot, RSO) triple over the stacked scan outputs —
:func:`collect_candidates` is one scan dispatch plus one match dispatch
per recording, and :func:`collect_candidates_many` batches a whole
validation suite through ``vmap`` so :func:`threshold_sweep` executes in
O(1) device dispatches total. The numpy matcher
(:func:`collect_candidates_numpy`) and the per-cluster Python loop
(:func:`collect_candidates_loop`) are kept as oracles.

Precision contract: the device matcher evaluates gate distances in
float32 (x64 stays off) while the numpy oracle uses float64, so their
agreement is exact *except* for candidates within float32 rounding
(~1e-4 px after time rebasing) of the 14 px gate boundary — a
measure-zero set the continuous-valued synthetic suite never hits; the
score-equality tests pin the agreement on that suite, not a structural
bit-identity like the pipeline drivers'.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.events import WindowedEvents
from repro.core.pipeline.config import PipelineConfig
from repro.core.pipeline.scan import _many_scan_raw, run_recording_scan

from typing import TYPE_CHECKING

if TYPE_CHECKING:  # avoid circular import (data.synthetic uses core.events)
    from repro.data.synthetic import Recording


@dataclasses.dataclass
class DetectionScore:
    tp: int = 0  # cluster >= threshold and is a true RSO
    fp: int = 0  # cluster >= threshold but star/noise
    fn: int = 0  # candidate RSO cluster rejected by threshold
    tn: int = 0  # star/noise candidate correctly rejected

    @property
    def accuracy(self) -> float:
        total = self.tp + self.fp + self.fn + self.tn
        return (self.tp + self.tn) / total if total else 0.0

    @property
    def precision(self) -> float:
        d = self.tp + self.fp
        return self.tp / d if d else 0.0

    @property
    def recall(self) -> float:
        d = self.tp + self.fn
        return self.tp / d if d else 0.0


@dataclasses.dataclass
class Candidates:
    """Pipeline outputs collected once; thresholds are swept afterwards.

    Cluster level: every candidate cluster (>= candidate_floor events) with
    its event count and ground-truth flag (centroid within the gate radius
    of a true RSO position at the cluster's mean time).

    Object level: for every (window, visible RSO) pair, the best (max)
    count among clusters matched to that RSO — used for miss (FN) scoring,
    mirroring the paper's protocol of verifying detections against known
    RSO *trajectories* rather than counting sub-threshold fragments of an
    already-detected object as misses.
    """

    counts: np.ndarray  # (C,) candidate cluster event counts
    is_rso: np.ndarray  # (C,) bool
    object_best: np.ndarray  # (V,) best matched count per visible-object-window


def _floor_config(config: PipelineConfig, candidate_floor: int) -> PipelineConfig:
    floor_grid = dataclasses.replace(config.grid, min_events=candidate_floor)
    return dataclasses.replace(config, grid=floor_grid)


# ---------------------------------------------------------------------------
# Device-resident truth matching.
# ---------------------------------------------------------------------------

def track_table(tracks) -> np.ndarray:
    """Normalize an RSO trajectory table to (R, 6) float64
    ``[x0, y0, vx, vy, ax, ay]``.

    Legacy recordings carry (R, 4) constant-velocity rows; the scenario
    simulator's ballistic family adds constant-acceleration columns.
    Zero-padding the accel columns keeps every matcher bit-compatible
    with the 4-column era (``x + 0.0`` is exact in IEEE float).
    """
    a = np.asarray(tracks, np.float64)
    if a.size == 0:
        return np.zeros((0, 6))
    a = a.reshape(-1, a.shape[-1])
    if a.shape[-1] == 4:
        a = np.concatenate([a, np.zeros((a.shape[0], 2))], axis=1)
    return a


def track_positions(tracks: np.ndarray, ts):
    """Trajectory positions at times ``ts`` (seconds) for a (R, 6) table
    broadcast against ``ts[..., None]``; works for numpy and jnp inputs."""
    px = tracks[..., 0] + tracks[..., 2] * ts + 0.5 * tracks[..., 4] * ts * ts
    py = tracks[..., 1] + tracks[..., 3] * ts + 0.5 * tracks[..., 5] * ts * ts
    return px, py


def _match_core(counts, valid, cx, cy, ct, t_start, tracks, gate_px, max_samples):
    """Match every (window, slot) centroid against every RSO trajectory.

    Inputs are the stacked scan outputs for one recording: (W, K) cluster
    arrays, (W,) float32 window origins (microseconds, rebased to the
    recording's first window by :func:`_rebase_times` so float32 keeps
    sub-pixel trajectory precision over arbitrarily long streams), and
    (R, 6) [x0, y0, vx, vy, ax, ay] trajectories shifted to the same
    origin. Returns ``(is_rso (W, K), keep (W, K), best (W, R))`` where
    ``keep`` marks the window-major candidate prefix under
    ``max_samples`` and ``best`` is the max kept count matched to each
    (window, RSO) pair.
    """
    t_ev = t_start[:, None] + ct  # (W, K) us, recording-relative
    ts = t_ev[:, :, None] * 1e-6  # seconds, (W, K, 1)
    px, py = track_positions(tracks[None, None, :, :], ts)  # (W, K, R)
    dx = px - cx[:, :, None]
    dy = py - cy[:, :, None]
    matched = jnp.sqrt(dx * dx + dy * dy) <= gate_px  # (W, K, R)

    flat_valid = valid.reshape(-1)
    rank = jnp.cumsum(flat_valid.astype(jnp.int32)) - 1
    keep = (flat_valid & (rank < max_samples)).reshape(valid.shape)
    contrib = jnp.where(matched & keep[:, :, None], counts[:, :, None], 0)
    return matched.any(axis=-1), keep, contrib.max(axis=1)


_match_one = jax.jit(_match_core)
_match_many = jax.jit(jax.vmap(_match_core, in_axes=(0, 0, 0, 0, 0, 0, 0, None, 0)))

# Padding trajectory for vmapped matching over recordings with different
# RSO counts: parked far outside the sensor, zero velocity -> never gates.
_FAR_TRACK = (1e9, 1e9, 0.0, 0.0, 0.0, 0.0)


def _rebase_times(
    t_start_us: np.ndarray, tracks: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Rebase window origins to the recording's first window (host, f64).

    Absolute microsecond timestamps overflow int32 after ~36 min and lose
    float32 precision long before that; window origins *relative to the
    recording* stay small (resolution better than 1 us per 16 s of
    stream, i.e. sub-0.01 px at RSO speeds). Trajectory intercepts and
    velocities (the (R, 6) table may carry constant acceleration) are
    advanced to the same origin in float64 before the cast.
    """
    t_ref_us = int(t_start_us[0]) if len(t_start_us) else 0
    t_rel = (t_start_us - t_ref_us).astype(np.float32)
    shifted = track_table(tracks)
    if shifted.size:
        dt = t_ref_us * 1e-6
        shifted[:, 0] += shifted[:, 2] * dt + 0.5 * shifted[:, 4] * dt * dt
        shifted[:, 1] += shifted[:, 3] * dt + 0.5 * shifted[:, 5] * dt * dt
        shifted[:, 2] += shifted[:, 4] * dt
        shifted[:, 3] += shifted[:, 5] * dt
    return t_rel, shifted.astype(np.float32)


def _visible_objects(
    recording: Recording,
    stops: np.ndarray,
    n_rso: int,
    min_truth_events: int,
) -> np.ndarray:
    """(W, R) bool — (window, RSO) pairs with enough true events to count
    as visible (host-side: depends only on ground-truth labels).
    ``stops`` are the windows' exclusive slice stops into the recording
    (one per window, in stream order)."""
    from repro.data.synthetic import KIND_RSO

    w_count = len(stops)
    n_true = np.zeros((w_count, n_rso), np.int64)
    rso_ev = np.flatnonzero(np.asarray(recording.kind) == KIND_RSO)
    if rso_ev.size and w_count:
        # Dual-threshold windows partition the stream: event e lands in the
        # window whose stop is the first one strictly past e. Events past
        # the last stop (none, by construction) are dropped defensively.
        ev_w = np.searchsorted(stops, rso_ev, side="right")
        in_range = ev_w < w_count
        np.add.at(
            n_true,
            (ev_w[in_range], np.asarray(recording.obj)[rso_ev[in_range]]),
            1,
        )
    return n_true >= min_truth_events


def _assemble_candidates(
    recording: Recording,
    stops: np.ndarray,  # (W,) window slice stops
    counts: np.ndarray,  # (W, K)
    is_rso: np.ndarray,  # (W, K)
    keep: np.ndarray,  # (W, K)
    best: np.ndarray,  # (W, R)
    min_truth_events: int,
) -> Candidates:
    n_rso = best.shape[-1]
    keep_flat = keep.reshape(-1)
    counts_out = counts.reshape(-1)[keep_flat].astype(np.int32)
    is_rso_out = is_rso.reshape(-1)[keep_flat]
    visible = _visible_objects(recording, stops, n_rso, min_truth_events)
    return Candidates(
        counts_out,
        np.asarray(is_rso_out, bool),
        np.asarray(best[visible], np.int32),
    )


def collect_candidates(
    recording: Recording,
    config: PipelineConfig = PipelineConfig(),
    candidate_floor: int = 2,
    max_samples: int | None = None,
    gate_px: float = 14.0,
    min_truth_events: int = 3,
) -> Candidates:
    """Run the scanned pipeline ONCE over a recording and collect candidates.

    Truth matching runs on device over the stacked scan outputs (one
    matcher dispatch for all (window, slot, object) triples); only the
    ground-truth visibility bookkeeping — a function of the simulator
    labels, not of pipeline outputs — stays on host. Ordering,
    ``max_samples`` truncation, and object-level bookkeeping match
    :func:`collect_candidates_numpy` / :func:`collect_candidates_loop`
    (the oracles) exactly.
    """
    result = run_recording_scan(
        recording, _floor_config(config, candidate_floor), with_tracking=False
    )
    windows = result.windows
    cl = result.clusters
    t_rel, tracks = _rebase_times(windows.t_start_us, recording.rso_tracks)
    k = cl.count.shape[-1] if cl.count.ndim == 2 else 0
    ms = windows.num_windows * k if max_samples is None else max_samples
    is_rso, keep, best = _match_one(
        cl.count, cl.valid, cl.centroid_x, cl.centroid_y, cl.centroid_t,
        jnp.asarray(t_rel), jnp.asarray(tracks),
        jnp.float32(gate_px), ms,
    )
    return _assemble_candidates(
        recording, windows.stops, np.asarray(cl.count), np.asarray(is_rso),
        np.asarray(keep), np.asarray(best), min_truth_events,
    )


def collect_candidates_many(
    recordings: list[Recording],
    config: PipelineConfig = PipelineConfig(),
    candidate_floor: int = 2,
    max_samples: int | None = None,
    gate_px: float = 14.0,
    min_truth_events: int = 3,
) -> list[Candidates]:
    """Candidates for a whole suite in O(1) device dispatches.

    One vmapped scan over all recordings (padded to a common window
    count) + one vmapped matcher call (trajectories padded to a common
    RSO count with far-away parked tracks). Per-recording results equal
    :func:`collect_candidates` exactly; padded windows carry no valid
    clusters and padded tracks never gate, so neither contributes.
    """
    if not recordings:
        return []
    floor_cfg = _floor_config(config, candidate_floor)
    windowed, (_, clusters, _, _) = _many_scan_raw(
        recordings, floor_cfg, with_tracking=False
    )
    k = clusters.count.shape[-1]
    w_max = clusters.count.shape[1]
    rebased = [
        _rebase_times(w.t_start_us, r.rso_tracks)
        for r, w in zip(recordings, windowed)
    ]
    tracks = [t for _, t in rebased]
    r_max = max((t.shape[0] for t in tracks), default=0)
    tracks_padded = np.stack(
        [
            np.concatenate(
                [t, np.tile(np.float32(_FAR_TRACK), (r_max - t.shape[0], 1))]
            ) if t.shape[0] < r_max else t
            for t in tracks
        ]
    ) if r_max else np.zeros((len(recordings), 0, 6), np.float32)
    t_starts = np.stack(
        [
            np.pad(t_rel, (0, w_max - len(t_rel))).astype(np.float32)
            for t_rel, _ in rebased
        ]
    )
    ms = np.asarray(
        [
            w.num_windows * k if max_samples is None else max_samples
            for w in windowed
        ],
        np.int32,
    )
    is_rso, keep, best = _match_many(
        clusters.count, clusters.valid, clusters.centroid_x,
        clusters.centroid_y, clusters.centroid_t,
        jnp.asarray(t_starts), jnp.asarray(tracks_padded),
        jnp.float32(gate_px), jnp.asarray(ms),
    )
    counts_np = np.asarray(clusters.count)
    is_rso_np, keep_np, best_np = (
        np.asarray(is_rso), np.asarray(keep), np.asarray(best)
    )
    out: list[Candidates] = []
    for r, (rec, w) in enumerate(zip(recordings, windowed)):
        n, n_rso = w.num_windows, tracks[r].shape[0]
        out.append(
            _assemble_candidates(
                rec, w.stops, counts_np[r, :n], is_rso_np[r, :n, :],
                keep_np[r, :n, :], best_np[r, :n, :n_rso], min_truth_events,
            )
        )
    return out


def collect_candidates_fleet(
    recordings: list[Recording],
    config: PipelineConfig = PipelineConfig(),
    candidate_floor: int = 2,
    max_samples: int | None = None,
    gate_px: float = 14.0,
    min_truth_events: int = 3,
    mesh=None,
) -> list[Candidates]:
    """Candidates for a whole constellation via the fleet engine, O(1)
    dispatches.

    Each recording becomes one fleet sensor; the suite runs as ONE
    vmapped feed (every sensor's closed windows) + ONE vmapped flush
    (trailing windows) + ONE vmapped matcher call over the stacked fleet
    outputs. Padded window slots (sensors close different window counts)
    carry no valid clusters, so the matcher's rank/keep bookkeeping
    skips them and per-recording results equal
    :func:`collect_candidates_many` exactly. ``mesh`` (a mesh with a
    ``sensor`` axis) shards the fleet carry across devices.
    """
    from repro.core.pipeline.fleet import FleetPipeline

    if not recordings:
        return []
    floor_cfg = _floor_config(config, candidate_floor)
    fleet = FleetPipeline(
        floor_cfg, n_sensors=len(recordings), with_tracking=False, mesh=mesh
    )
    head = fleet.feed([(r.x, r.y, r.t, r.p) for r in recordings])
    tail = fleet.flush()
    parts = [p for p in (head, tail) if p.clusters is not None]
    s_count = len(recordings)
    k = config.grid.max_clusters
    if not parts:  # nothing closed anywhere (all-empty recordings)
        return [
            Candidates(
                np.zeros(0, np.int32), np.zeros(0, bool), np.zeros(0, np.int32)
            )
            for _ in recordings
        ]
    if len(parts) == 2:
        cl = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b], axis=1),
            parts[0].clusters, parts[1].clusters,
        )
    else:
        cl = parts[0].clusters
    # Real-slot bookkeeping: sensor s occupies rows [0, n_head) of the
    # feed block and [w_head, w_head + n_tail) of the flush block.
    offsets = np.cumsum([0] + [p.clusters.count.shape[1] for p in parts])[:-1]
    w_total = cl.count.shape[1]
    t_grid = np.zeros((s_count, w_total), np.float32)
    rows_all, stops_all, tracks = [], [], []
    ms = np.zeros(s_count, np.int32)
    for s, rec in enumerate(recordings):
        t_start_us = np.concatenate([p.windows[s].t_start_us for p in parts])
        stops = np.concatenate([p.windows[s].stops for p in parts])
        rows = np.concatenate(
            [off + np.arange(int(p.n_windows[s])) for off, p in zip(offsets, parts)]
        ).astype(np.int64)
        t_rel, shifted = _rebase_times(t_start_us, rec.rso_tracks)
        t_grid[s, rows] = t_rel
        rows_all.append(rows)
        stops_all.append(stops)
        tracks.append(shifted)
        ms[s] = len(rows) * k if max_samples is None else max_samples
    r_max = max((t.shape[0] for t in tracks), default=0)
    tracks_padded = np.stack(
        [
            np.concatenate(
                [t, np.tile(np.float32(_FAR_TRACK), (r_max - t.shape[0], 1))]
            ) if t.shape[0] < r_max else t
            for t in tracks
        ]
    ) if r_max else np.zeros((s_count, 0, 6), np.float32)
    is_rso, keep, best = _match_many(
        cl.count, cl.valid, cl.centroid_x, cl.centroid_y, cl.centroid_t,
        jnp.asarray(t_grid), jnp.asarray(tracks_padded),
        jnp.float32(gate_px), jnp.asarray(ms),
    )
    counts_np = np.asarray(cl.count)
    is_rso_np, keep_np, best_np = (
        np.asarray(is_rso), np.asarray(keep), np.asarray(best)
    )
    out: list[Candidates] = []
    for s, rec in enumerate(recordings):
        rows, n_rso = rows_all[s], tracks[s].shape[0]
        out.append(
            _assemble_candidates(
                rec, stops_all[s], counts_np[s][rows], is_rso_np[s][rows],
                keep_np[s][rows], best_np[s][rows][:, :n_rso],
                min_truth_events,
            )
        )
    return out


# ---------------------------------------------------------------------------
# Threshold scoring / sweeps.
# ---------------------------------------------------------------------------

def score_threshold(cand: Candidates, thr: int) -> DetectionScore:
    passed = cand.counts >= thr
    return DetectionScore(
        tp=int(np.sum(passed & cand.is_rso)),
        fp=int(np.sum(passed & ~cand.is_rso)),
        fn=int(np.sum(cand.object_best < thr)),
        tn=int(np.sum(~passed & ~cand.is_rso)),
    )


def merge_candidates(cands: list[Candidates]) -> Candidates:
    return Candidates(
        np.concatenate([c.counts for c in cands]) if cands else np.zeros(0, np.int32),
        np.concatenate([c.is_rso for c in cands]) if cands else np.zeros(0, bool),
        np.concatenate([c.object_best for c in cands]) if cands else np.zeros(0, np.int32),
    )


def evaluate_detection(
    recording: Recording,
    config: PipelineConfig = PipelineConfig(),
    min_events: int | None = None,
    candidate_floor: int = 2,
    max_samples: int | None = None,
) -> DetectionScore:
    """Score the min_events detector against simulator ground truth
    (the paper's Fig. 10b / Sec. V-A protocol)."""
    thr = config.grid.min_events if min_events is None else min_events
    cand = collect_candidates(recording, config, candidate_floor, max_samples)
    return score_threshold(cand, thr)


def threshold_sweep(
    recordings: list[Recording],
    thresholds: tuple[int, ...] = (2, 3, 4, 5, 6, 8, 10),
    config: PipelineConfig = PipelineConfig(),
    max_samples_per_recording: int | None = None,
    driver: str = "scan",
) -> dict[int, DetectionScore]:
    """Accuracy vs min_events across a validation suite (paper Fig. 10b).

    The whole suite runs in O(1) device dispatches and thresholds are
    swept over the collected candidates on host (the O(n) single-pass
    property in action). ``driver="scan"`` (default) batches the suite
    through the vmapped offline scan (:func:`collect_candidates_many`);
    ``driver="fleet"`` routes it through the streaming fleet engine
    (:func:`collect_candidates_fleet`) — same scores exactly, but
    exercising the serving path, and shardable over a ``sensor`` mesh
    axis.
    """
    if driver == "scan":
        cands = collect_candidates_many(
            recordings, config, max_samples=max_samples_per_recording
        )
    elif driver == "fleet":
        cands = collect_candidates_fleet(
            recordings, config, max_samples=max_samples_per_recording
        )
    else:
        raise ValueError(f"unknown threshold_sweep driver: {driver!r}")
    cand = merge_candidates(cands)
    return {thr: score_threshold(cand, thr) for thr in thresholds}
