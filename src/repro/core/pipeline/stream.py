"""Resumable streaming pipeline engine: chunked feeds, per-window latency.

The paper's headline claim is deterministic sub-62 ms processing of a
*live* event-camera feed. ``StreamingPipeline`` is that driver: raw event
chunks of arbitrary size go in via :meth:`StreamingPipeline.feed`, and
every feed returns the clusters / metrics / tracks of the windows that
provably closed — windowed with exactly the dual-threshold semantics of
the offline drivers, so the concatenation of all feeds (plus a final
:meth:`flush`) is **bit-identical to ``run_recording_scan`` over the same
recording for any chunking**, including chunks that split a window.

The carry (:class:`StreamState`) holds everything the next feed needs:

* the dual-threshold batcher remainder — host-side events of the still
  open trailing window (no future event can be excluded from it yet),
* the window counter — the next atlas tag (epoch-local: it restarts
  when the tag encoding rolls over to a fresh epoch),
* the persistent window-tagged event atlas (event-space metrics path) —
  never cleared between feeds; stale pixels fail the tag check,
* the tracker :class:`~repro.core.tracking.TrackState`.

The device step (``make_stream_fn``) donates the atlas buffer, so a
steady-state feed allocates only its per-window outputs. Consequence: a
:class:`StreamState` is consumed by the feed that processes it — resume
from the *latest* state only; forking one saved state into two pipelines
would reuse a donated buffer.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics as M
from repro.core.events import (
    EventBatch,
    WindowedEvents,
    dense_wire_bytes,
    dual_threshold_bounds,
    dual_threshold_closed_bounds,
    monotone_merge,
    pack_bounds,
    pack_wire,
    ragged_wire_bytes,
)
from repro.core.grid_clustering import Clusters
from repro.core.pipeline.config import PipelineConfig
from repro.core.pipeline.scan import ScanResult, make_atlas, make_stream_fn
from repro.core.tracking import TrackState, init_tracks

_EMPTY = np.zeros(0, np.int64)


def tag_limit(config: PipelineConfig) -> int:
    """Windows addressable within one atlas tag epoch for this config.

    Tags are encoded as ``(tag + 1) << shift`` in int32 (``shift`` bits
    hold the per-pixel count); the streaming drivers must wrap to a fresh
    epoch — atlas re-zeroed so stale pixels cannot alias fresh tags —
    before the encoding overflows.
    """
    shift = max(config.batcher.capacity.bit_length(), 1)
    return (1 << (31 - shift)) - 2


def empty_scan_result(
    config: PipelineConfig,
    with_tracking: bool,
    tracks: TrackState,
    windows: WindowedEvents,
) -> ScanResult:
    """Zero-window ScanResult (a feed that closed nothing): empty stacked
    outputs with the caller's carry passed through as ``final_tracks``."""
    k = config.grid.max_clusters
    f32 = lambda: jnp.zeros((0, k), jnp.float32)
    i32 = lambda: jnp.zeros((0, k), jnp.int32)
    clusters = Clusters(
        centroid_x=f32(), centroid_y=f32(), centroid_t=f32(),
        count=i32(), cell_x=i32(), cell_y=i32(),
        valid=jnp.zeros((0, k), bool),
    )
    mets = {name: f32() for name in M.METRIC_NAMES}
    states = jax.tree.map(lambda a: jnp.zeros((0,) + a.shape, a.dtype), tracks)
    return ScanResult(
        t_start_us=windows.t_start_us,
        clusters=clusters,
        metrics=mets,
        tracks=states if with_tracking else None,
        final_tracks=tracks if with_tracking else None,
        windows=windows,
    )


@dataclasses.dataclass
class StreamState:
    """Everything carried between feeds; replaceable/savable as a unit."""

    pending: tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]  # x, y, t, p
    events_consumed: int  # stream index of pending[0]
    next_tag: int  # next atlas tag (epoch-local: resets at tag rollover)
    atlas: jax.Array  # persistent tagged event surface
    tracks: TrackState
    last_t: int | None = None  # newest absorbed timestamp (feed monotonicity)

    @property
    def pending_count(self) -> int:
        return len(self.pending[2])


class StreamingPipeline:
    """Incremental driver over a live event stream.

    >>> sp = StreamingPipeline(PipelineConfig())
    >>> for x, y, t, p in sensor_chunks():      # any chunk sizes
    ...     result = sp.feed(x, y, t, p)        # windows closed this feed
    >>> tail = sp.flush()                       # close the trailing window

    Each feed runs ONE jit'd (donated-carry) step over the newly closed
    windows; results are bit-identical to ``run_recording_scan`` over the
    concatenated stream. ``state`` may be saved and restored to resume a
    stream across processes (host remainder + device carry).
    """

    def __init__(
        self,
        config: PipelineConfig = PipelineConfig(),
        with_tracking: bool = True,
        state: StreamState | None = None,
        wire: str = "dense",
    ):
        if wire not in ("dense", "ragged"):
            raise ValueError(f"unknown wire mode: {wire!r}")
        self.config = config
        self.with_tracking = with_tracking
        self.wire = wire
        self._step = make_stream_fn(config, with_tracking)
        # Lazy import: fleet.py imports this module at load time, so the
        # wire machinery (shared with the fleet engine) has to come in at
        # construction, not at module import.
        from repro.core.pipeline.fleet import (
            WireStats, _stage_wire, make_wire_fn,
        )

        self.wire_stats = WireStats()
        if wire == "ragged":
            self._wire = make_wire_fn(config.batcher.capacity, config.use_kernels)
            self._stage_wire = _stage_wire
        else:
            self._wire = None
        self._tag_limit = tag_limit(config)
        self.state = self.init_state() if state is None else state

    def init_state(self) -> StreamState:
        return StreamState(
            pending=(_EMPTY, _EMPTY, _EMPTY, _EMPTY),
            events_consumed=0,
            next_tag=0,
            atlas=make_atlas(self.config),
            tracks=init_tracks(self.config.tracker),
        )

    def feed(
        self, x: np.ndarray, y: np.ndarray, t: np.ndarray, p: np.ndarray
    ) -> ScanResult:
        """Ingest a raw event chunk; process and return the closed windows.

        Events must be time-sorted within the chunk and non-decreasing
        across feeds; a chunk violating either raises ``ValueError``
        before any state changes (silent mis-windowing would otherwise
        corrupt every window downstream of the disorder). A feed may
        close zero windows (chunk too small/recent) — the result is then
        empty and the events wait in the batcher remainder. A feed that
        would close more windows than one tag epoch can address raises
        ``ValueError`` *without absorbing the chunk*, so the caller can
        re-feed it in smaller pieces.
        """
        merged = monotone_merge(
            self.state.pending, x, y, t, p, self.state.last_t
        )
        bounds, consumed = dual_threshold_closed_bounds(
            merged[2], self.config.batcher
        )
        return self._emit(merged, bounds, consumed)

    def feed_chunk(
        self, chunk: tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray] | None
    ) -> ScanResult:
        """:meth:`feed` over a packed ``(x, y, t, p)`` chunk tuple — the
        wire shape the fleet/service layers pass around (``None`` = idle,
        an empty feed). Lets a dedicated single-sensor pipeline consume
        the exact per-session chunk stream a
        :class:`~repro.serve.service.DetectionService` session receives,
        which is how the service's bit-identity contract is pinned."""
        if chunk is None:
            chunk = (_EMPTY, _EMPTY, _EMPTY, _EMPTY)
        return self.feed(*chunk)

    @property
    def backlog(self) -> int:
        """Events absorbed but not yet windowed (the batcher remainder)."""
        return self.state.pending_count

    def flush(self) -> ScanResult:
        """Close and process the trailing partial window (end of stream).

        After a flush the pipeline keeps accepting feeds — but the flushed
        window closed at the flush boundary, so only the full-stream
        equivalence of feeds *up to* the flush is preserved.
        """
        pending = self.state.pending
        bounds = dual_threshold_bounds(pending[2], self.config.batcher)
        return self._emit(pending, bounds, len(pending[2]))

    def _emit(
        self,
        pending: tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray],
        bounds: list[tuple[int, int]],
        consumed: int,
    ) -> ScanResult:
        n = len(bounds)
        if n > self._tag_limit:
            # More windows than one tag epoch can address: tags past the
            # limit would wrap the int32 encoding and silently alias stale
            # atlas pixels. Refuse before touching any state, so the
            # pipeline stays usable and the chunk can be re-fed in pieces.
            raise ValueError(
                f"feed closed {n} windows, more than one tag epoch "
                f"({self._tag_limit}) can address; split the feed"
            )
        st = self.state
        px, py, pt, pp = pending
        last_t = int(pt[-1]) if len(pt) else st.last_t
        cap = self.config.batcher.capacity
        bounds3 = [(s, e, int(pt[s])) for s, e in bounds]
        if self.wire == "ragged" and n:
            # Compressed ingest: pack the ragged wire on host, decode to
            # the dense (W, cap) planes device-side — bit-identical to
            # pack_bounds (see events.unpack_wire), one sensor row.
            wire, starts, stops, t_start, overflow = pack_wire(
                px, py, pt, pp, bounds3, cap
            )
            packed, valid = self._wire(*self._stage_wire(wire))
            batch = EventBatch(
                packed[0, 0], packed[1, 0], packed[2, 0], packed[3, 0],
                valid[0],
            )
            windows = WindowedEvents(batch, t_start, starts, stops, overflow)
            self.wire_stats.rounds += 1
            self.wire_stats.events += int(
                np.minimum(stops - starts, cap).sum()
            )
            self.wire_stats.wire_bytes += ragged_wire_bytes(
                wire[0].shape[0], 1, n, wire[4].shape[1]
            )
            self.wire_stats.dense_bytes += dense_wire_bytes(1, n, cap)
        else:
            windows = pack_bounds(px, py, pt, pp, bounds3, cap)
            if n:
                b = dense_wire_bytes(1, n, cap)
                self.wire_stats.rounds += 1
                self.wire_stats.events += int(
                    np.minimum(windows.stops - windows.starts, cap).sum()
                )
                self.wire_stats.wire_bytes += b
                self.wire_stats.dense_bytes += b
        # Slice indices are stream-global, like pad_windows over the
        # whole recording.
        windows = windows._replace(
            starts=windows.starts + st.events_consumed,
            stops=windows.stops + st.events_consumed,
        )
        if n == 0:
            # Absorb the new events into the remainder even when nothing
            # closed yet.
            self.state = dataclasses.replace(
                st, pending=pending, last_t=last_t
            )
            return empty_scan_result(
                self.config, self.with_tracking, st.tracks, windows
            )

        atlas, tag0 = st.atlas, st.next_tag
        if tag0 + n > self._tag_limit:  # tag epoch rollover
            atlas, tag0 = jnp.zeros_like(atlas), 0
        final, clusters, mets, states, atlas = self._step(
            windows.batch, st.tracks, atlas, tag0
        )
        keep = consumed  # events consumed from the front of the remainder
        self.state = StreamState(
            pending=(px[keep:], py[keep:], pt[keep:], pp[keep:]),
            events_consumed=st.events_consumed + keep,
            next_tag=tag0 + n,
            atlas=atlas,
            tracks=final,
            last_t=last_t,
        )
        return ScanResult(
            t_start_us=windows.t_start_us,
            clusters=clusters,
            metrics=mets,
            tracks=states if self.with_tracking else None,
            final_tracks=final if self.with_tracking else None,
            windows=windows,
        )
