"""The event-space step core: phased, atlas-carrying, O(E + K*48^2)/window.

Split out of ``scan.py`` so the scheduling-heavy phase machinery lives in
one place; see DESIGN.md Sec. 5 for the design and ``scan.py`` for the
core's carry contract (state, atlas, tag0).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import metrics as M
from repro.core.events import _PAIRWISE_MAX_EVENTS, EventBatch, roi_filter
from repro.core.pipeline.config import PipelineConfig, _histogram_fn
from repro.core.pipeline.window_core import _cluster, _condition
from repro.core.tracking import TrackState, tracker_step


def _fused_condition_normalizer(config: PipelineConfig, width: int, height: int):
    """Conditioning + event normalizer sharing ONE (E, E) same-pixel block.

    :func:`~repro.core.events.persistent_event_filter` (hot-pixel rate)
    and :func:`~repro.core.metrics.coincidence_counts` (normalizer /
    leaders) each build the identical pairwise same-pixel compare matrix
    at window capacities; on CPU that redundant (E, E) pass is a
    measurable slice of the fleet step. This fused form computes the
    matrix once and reuses it for both — every output is the exact same
    integer/boolean the two-pass route produces (the hot-pixel count
    weights by pre-filter validity, the coincidence count and the
    lowest-index leader by post-filter in-bounds validity), so all
    drivers remain bit-identical. Returns ``(batch, c, leader, w, norm)``
    like ``_condition`` + ``event_normalizer`` chained.
    """

    def run(batch: EventBatch):
        batch = roi_filter(batch, config.roi)
        same = (batch.x[:, None] == batch.x[None, :]) & (
            batch.y[:, None] == batch.y[None, :]
        )
        hot = jnp.sum(same & batch.valid[None, :], axis=-1)
        batch = batch._replace(valid=batch.valid & (hot <= config.hot_pixel_max))
        inb = (
            (batch.x >= 0) & (batch.x < width)
            & (batch.y >= 0) & (batch.y < height)
        )
        w = batch.valid & inb
        sw = same & w[None, :]
        c = jnp.sum(sw, axis=-1, dtype=jnp.int32)
        leader = w & ~jnp.any(jnp.tril(sw, k=-1), axis=-1)
        norm = jnp.maximum(jnp.max(jnp.where(w, c, 0)).astype(jnp.float32), 1.0)
        return batch, c, leader, w, norm

    return run


def _make_event_core(config: PipelineConfig, with_tracking: bool):
    """Event-space step core: O(events + K * patch^2) per window.

    Three phases, all inside one jit (DESIGN.md Sec. 5):

    1. **Batched conditioning + clustering + event stats** — windows are
       processed in ``scan_chunk`` blocks under ``lax.map`` so the
       pairwise hot-pixel filter, cell histogram, coincidence sort, and
       histogram matmul vectorize across windows while staying
       cache-resident.
    2. **Event-surface scan** — the persistent sensor-sized int32 atlas
       (passed in as carry, returned updated); each window writes its
       <= E leader pixels tagged ``tag0 + w`` (O(E), no per-window clear
       — stale pixels fail the tag check) and slices K count patches
       back out. This is the BRAM-resident accumulator a fabric
       implementation would use: memory is O(sensor), but per-window
       work is O(E + K * patch^2). The shared exact metric core runs
       batched per chunk.
    3. Outputs are truncated back to the true window count; the tracker
       scans over the true windows only.

    Results are bit-identical to the frame-based core and invariant to
    how windows are split across core calls (given monotone tags).
    """
    hist_fn = _histogram_fn(config)
    grid = config.grid
    width, height = grid.width, grid.height
    window = M.WINDOW

    def core(stacked: EventBatch, state: TrackState, atlas: jax.Array, tag0):
        w_total, cap = stacked.x.shape
        assert atlas.shape == (height + 1, max(width, cap)), atlas.shape
        chunk = max(1, min(config.scan_chunk, max(w_total, 1)))
        pad = (-w_total) % chunk
        if pad:
            padded = jax.tree.map(
                lambda a: jnp.concatenate(
                    [a, jnp.zeros((pad,) + a.shape[1:], a.dtype)], axis=0
                ),
                stacked,
            )
        else:
            padded = stacked
        w_pad = w_total + pad
        n_chunks = w_pad // chunk
        chunked = jax.tree.map(
            lambda a: a.reshape(n_chunks, chunk, *a.shape[1:]), padded
        )

        fused = (
            _fused_condition_normalizer(config, width, height)
            if cap <= _PAIRWISE_MAX_EVENTS and jax.default_backend() == "cpu"
            else None
        )

        def phase_window(batch: EventBatch):
            if fused is not None:
                batch, c, leader, wmask, norm = fused(batch)
            else:
                batch = _condition(config, batch)
                c, leader, wmask, norm = M.event_normalizer(batch, width, height)
            clusters = _cluster(config, hist_fn, batch)
            x0, y0 = M.window_origin(
                clusters.centroid_x, clusters.centroid_y, width, height
            )
            hist, moments = M.event_histogram_counts(
                batch, c, leader, wmask, norm, x0, y0
            )
            return (batch.x, batch.y, c, leader, norm, x0, y0, hist, moments, clusters)

        outs = jax.lax.map(lambda cb: jax.vmap(phase_window)(cb), chunked)
        outs = jax.tree.map(lambda a: a.reshape(w_pad, *a.shape[2:]), outs)
        ex, ey, c, leader, norm, x0, y0, hist, moments, clusters = outs

        # Phase 2: persistent tagged event surface + metrics.
        shift = max(cap.bit_length(), 1)  # pixel counts fit in `shift` bits
        mask = (1 << shift) - 1
        dump_x = jnp.arange(cap, dtype=jnp.int32)

        kmax = grid.max_clusters

        def window_patches(surface, inp):
            """One window: tag-write leader pixels, slice K count patches."""
            tag, bx, by, lead, c_w, x0w, y0w = inp
            enc = jnp.where(lead, ((tag + 1) << shift) | (c_w & mask), 0)
            ix = jnp.where(lead, bx, dump_x)
            iy = jnp.where(lead, by, height)
            surface = surface.at[iy, ix].set(
                enc, unique_indices=True, mode="promise_in_bounds"
            )

            def one_patch(x0k, y0k):
                tile = jax.lax.dynamic_slice(surface, (y0k, x0k), (window, window))
                return jnp.where(
                    (tile >> shift) == tag + 1, tile & mask, 0
                ).astype(jnp.float32)

            return surface, jax.vmap(one_patch)(x0w, y0w)

        def chunk_step(surface, inp):
            """One chunk: per-window patch extraction (sequential, shares
            the surface), then the dense metric core batched over the
            whole (chunk * K) patch block for vector width."""
            tag, bx, by, lead, c_w, norm_w, x0w, y0w, hist_w, mom_w, cl = inp
            surface, patches = jax.lax.scan(
                window_patches, surface, (tag, bx, by, lead, c_w, x0w, y0w)
            )
            mets = jax.vmap(M._exact_cluster_metrics)(
                patches.reshape(chunk * kmax, window, window),
                hist_w.reshape(chunk * kmax, -1),
                jnp.repeat(norm_w, kmax),
                cl.count.reshape(chunk * kmax),
                cl.valid.reshape(chunk * kmax),
                jax.tree.map(lambda a: a.reshape(chunk * kmax), mom_w),
            )
            return surface, {k: v.reshape(chunk, kmax) for k, v in mets.items()}

        tags = jnp.asarray(tag0, jnp.int32) + jnp.arange(w_pad, dtype=jnp.int32)
        rechunk = lambda a: a.reshape(n_chunks, chunk, *a.shape[1:])
        atlas, mets = jax.lax.scan(
            chunk_step,
            atlas,
            jax.tree.map(
                rechunk,
                (tags, ex, ey, leader, c, norm, x0, y0, hist, moments, clusters),
            ),
        )
        mets = {k: v.reshape(w_pad, kmax) for k, v in mets.items()}

        # Truncate the chunk padding, then track over the true windows only.
        trim = lambda a: a[:w_total]
        clusters = jax.tree.map(trim, clusters)
        mets = {k: trim(v) for k, v in mets.items()}

        if with_tracking:
            def track_step(carry, inp):
                cl, shannon = inp
                carry, _ = tracker_step(carry, cl, shannon, config.tracker)
                return carry, carry

            final, states = jax.lax.scan(
                track_step, state, (clusters, mets["shannon_entropy"])
            )
        else:
            final = state
            states = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (w_total,) + a.shape), state
            )
        return final, clusters, mets, states, atlas

    return core
