"""Clustering baselines from the paper's Table I: K-Means and DBSCAN.

The paper argues grid clustering dominates both for streaming event data
(O(n), single pass, no k, minimal state). To reproduce the comparison we
implement both baselines in JAX with fixed shapes so the complexity and
throughput claims can be benchmarked head-to-head
(``benchmarks/table1_algorithms.py``).

* :func:`kmeans` — Lloyd's algorithm, O(n * k * i), k-means++-style farthest
  point init, masked for padded events.
* :func:`dbscan` — O(n^2) pairwise-distance density clustering; label
  propagation over the core-point adjacency graph runs as an iterated
  min-label diffusion (matrix-vector, fixed iterations = ceil(log2 n) + safety)
  which is the TPU-friendly form of the BFS used on CPUs.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.events import EventBatch


class KMeansResult(NamedTuple):
    centroids: jax.Array  # (k, 2) float32
    assignment: jax.Array  # (E,) int32, -1 for invalid events
    counts: jax.Array  # (k,) int32


@partial(jax.jit, static_argnames=("k", "iters"))
def kmeans(batch: EventBatch, k: int = 8, iters: int = 16) -> KMeansResult:
    pts = jnp.stack([batch.x, batch.y], axis=-1).astype(jnp.float32)  # (E,2)
    valid = batch.valid
    big = jnp.float32(1e12)

    # Farthest-point init (deterministic k-means++ flavour).
    first = jnp.argmax(valid)  # first valid point

    def init_step(carry, _):
        cents, n_chosen = carry
        d = jnp.min(
            jnp.sum((pts[:, None, :] - cents[None, :, :]) ** 2, -1)
            + jnp.where(jnp.arange(k)[None, :] < n_chosen, 0.0, big),
            axis=1,
        )
        d = jnp.where(valid, d, -1.0)
        nxt = jnp.argmax(d)
        cents = cents.at[n_chosen].set(pts[nxt])
        return (cents, n_chosen + 1), None

    cents0 = jnp.zeros((k, 2), jnp.float32).at[0].set(pts[first])
    (cents, _), _ = jax.lax.scan(init_step, (cents0, 1), None, length=k - 1)

    def lloyd(cents, _):
        d = jnp.sum((pts[:, None, :] - cents[None, :, :]) ** 2, -1)  # (E,k)
        assign = jnp.argmin(d, axis=1)
        onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32) * valid[:, None]
        counts = onehot.sum(0)
        sums = onehot.T @ pts
        new = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1.0), cents)
        return new, None

    cents, _ = jax.lax.scan(lloyd, cents, None, length=iters)
    d = jnp.sum((pts[:, None, :] - cents[None, :, :]) ** 2, -1)
    assign = jnp.where(valid, jnp.argmin(d, axis=1), -1)
    counts = jnp.sum(
        jax.nn.one_hot(assign, k, dtype=jnp.int32) * valid[:, None].astype(jnp.int32), 0
    )
    return KMeansResult(cents, assign.astype(jnp.int32), counts)


class DBSCANResult(NamedTuple):
    labels: jax.Array  # (E,) int32 cluster label; -1 = noise/invalid
    n_clusters: jax.Array  # scalar int32
    core_mask: jax.Array  # (E,) bool


@partial(jax.jit, static_argnames=("eps", "min_pts"))
def dbscan(batch: EventBatch, eps: float = 8.0, min_pts: int = 5) -> DBSCANResult:
    pts = jnp.stack([batch.x, batch.y], axis=-1).astype(jnp.float32)
    valid = batch.valid
    n = pts.shape[0]
    d2 = jnp.sum((pts[:, None, :] - pts[None, :, :]) ** 2, -1)  # O(n^2)
    within = (d2 <= eps * eps) & valid[:, None] & valid[None, :]
    degree = within.sum(-1)
    core = (degree >= min_pts) & valid

    # Connectivity: core-core edges; border points attach to a core point.
    core_adj = within & core[:, None] & core[None, :]

    # Min-label diffusion: start with own index, iterate label = min over
    # core neighbours. log2(n) doublings suffice for path compression on
    # the doubled adjacency; we conservatively run 2*ceil(log2 n) steps.
    labels0 = jnp.where(core, jnp.arange(n), n)  # n = +inf sentinel

    def step(labels, _):
        neigh = jnp.where(core_adj, labels[None, :], n)
        new = jnp.minimum(labels, neigh.min(-1))
        # pointer jumping (path compression) => O(log n) convergence
        jumped = jnp.where(new < n, new[jnp.clip(new, 0, n - 1)], n)
        return jnp.minimum(new, jumped), None

    iters = 2 * max(1, n.bit_length())
    labels, _ = jax.lax.scan(step, labels0, None, length=iters)

    # Border points: adopt the min label among adjacent core points.
    border_neigh = jnp.where(within & core[None, :], labels[None, :], n)
    border_label = border_neigh.min(-1)
    final = jnp.where(core, labels, jnp.where(valid & (border_label < n), border_label, -1))

    # Compact labels to 0..C-1 by ranking unique roots.
    is_root = (final == jnp.arange(n)) & core
    rank = jnp.cumsum(is_root.astype(jnp.int32)) - 1
    compact = jnp.where(final >= 0, rank[jnp.clip(final, 0, n - 1)], -1)
    n_clusters = is_root.sum().astype(jnp.int32)
    return DBSCANResult(compact.astype(jnp.int32), n_clusters, core)


def dbscan_centroids(
    batch: EventBatch, result: DBSCANResult, max_clusters: int = 32
) -> tuple[jax.Array, jax.Array]:
    """Per-cluster centroids (max_clusters, 2) + counts, padded with -1."""
    onehot = jax.nn.one_hot(result.labels, max_clusters, dtype=jnp.float32)
    onehot = onehot * batch.valid[:, None]
    counts = onehot.sum(0)
    pts = jnp.stack([batch.x, batch.y], -1).astype(jnp.float32)
    sums = onehot.T @ pts
    cents = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1.0), -1.0)
    return cents, counts.astype(jnp.int32)
