"""End-to-end RSO detection pipeline (paper Fig. 2).

Stages, matching the paper's data flow:

  event capture -> conditioning (ROI + persistent-event removal)
    -> spatial quantization        [FPGA IP core -> Pallas kernel / jnp]
    -> cluster formation           [client software -> scatter + top-k]
    -> min_events threshold + metrics
    -> tracking (spatial-coherence validation)

Two drivers share one per-window core:

* ``run_recording`` — the legacy host loop: dual-threshold batching with
  one jit dispatch (and host sync) per window. Kept as the streaming
  reference — it is what a live sensor feed looks like.
* ``run_recording_scan`` — the device-resident path: ``pad_windows``
  stacks the whole recording into a (W, capacity) pytree, and a single
  ``jax.lax.scan`` runs conditioning -> histogram -> clustering ->
  metrics -> tracking over all windows in one dispatch, mirroring the
  FPGA's free-running stream. ``run_many_scan`` vmaps that scan over a
  batch of recordings (multi-sensor / multi-recording throughput).

``evaluate_detection`` scores accuracy against ground truth exactly as
the paper does (sampled detections verified against simulator truth);
candidate collection is vectorized over the stacked scan outputs.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics as M
from repro.core.events import (
    DEFAULT_ROI,
    BatcherConfig,
    EventBatch,
    WindowedEvents,
    dual_threshold_batches,
    pad_windows,
    persistent_event_filter,
    roi_filter,
)
from repro.core.grid_clustering import (
    Clusters,
    GridConfig,
    cell_histogram,
    clusters_from_histogram,
    merge_adjacent,
)
from repro.core.tracking import TrackerConfig, TrackState, init_tracks, tracker_step

from typing import TYPE_CHECKING

if TYPE_CHECKING:  # avoid circular import (data.synthetic uses core.events)
    from repro.data.synthetic import Recording


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    grid: GridConfig = GridConfig()
    batcher: BatcherConfig = BatcherConfig()
    tracker: TrackerConfig = TrackerConfig()
    roi: tuple[int, int, int, int] = DEFAULT_ROI
    hot_pixel_max: int = 12
    merge_neighbors: bool = False
    use_kernels: bool = False  # route quantize+accumulate through Pallas
    # Metrics implementation: "event" (frame-free, O(E + K*patch^2) per
    # window — the default), "frame" (sensor-sized accumulation image,
    # the bit-exactness oracle), or "kernel" (fused Pallas patch_metrics).
    metrics_impl: str = "event"
    # Window-block size for the event-space scan driver's batched phases
    # (cache-locality knob; results are invariant to it).
    scan_chunk: int = 8


def _histogram_fn(config: PipelineConfig) -> Callable[[EventBatch], tuple]:
    if config.use_kernels:
        # Imported lazily: kernels are optional at pipeline import time.
        from repro.kernels import ops as kops

        def fn(batch: EventBatch):
            # Trace-time call (no nested jit): shapes are static inside
            # both the per-window jit and the scan body.
            return kops.cluster_accum_call(
                batch.x, batch.y, batch.t, batch.valid,
                cell_size=config.grid.cell_size,
                grid_w=config.grid.grid_w,
                grid_h=config.grid.grid_h,
                width=config.grid.width,
                height=config.grid.height,
            )

        return fn
    return lambda batch: cell_histogram(batch, config.grid)


def _metrics_fn(
    config: PipelineConfig,
) -> Callable[[EventBatch, Clusters], dict[str, jax.Array]]:
    """Per-window metrics stage for the configured implementation."""
    impl = config.metrics_impl
    w, h = config.grid.width, config.grid.height
    if impl == "frame":
        return lambda batch, clusters: M.cluster_metrics_frame(batch, clusters, w, h)
    if impl == "event":
        return lambda batch, clusters: M.cluster_metrics_events(batch, clusters, w, h)
    if impl == "kernel":
        from repro.kernels import ops as kops

        return lambda batch, clusters: kops.patch_metrics_call(
            batch, clusters, width=w, height=h
        )
    raise ValueError(f"unknown metrics_impl: {impl!r}")


def _condition(config: PipelineConfig, batch: EventBatch) -> EventBatch:
    batch = roi_filter(batch, config.roi)
    return persistent_event_filter(batch, config.hot_pixel_max)


def _cluster(
    config: PipelineConfig, hist_fn: Callable[[EventBatch], tuple], batch: EventBatch
) -> Clusters:
    clusters = clusters_from_histogram(*hist_fn(batch), config.grid)
    if config.merge_neighbors:
        clusters = merge_adjacent(clusters, config.grid)
    return clusters


def _window_core(
    config: PipelineConfig,
    hist_fn: Callable[[EventBatch], tuple],
    metrics_fn: Callable[[EventBatch, Clusters], dict[str, jax.Array]],
    batch: EventBatch,
) -> tuple[Clusters, dict[str, jax.Array]]:
    """The per-window computation shared by the loop and scan drivers."""
    batch = _condition(config, batch)
    clusters = _cluster(config, hist_fn, batch)
    mets = metrics_fn(batch, clusters)
    return clusters, mets


def make_process_window(config: PipelineConfig = PipelineConfig()):
    """Build the jit'd per-window stage: conditioning -> clusters -> metrics.

    Note: each call returns a fresh jit closure, so a caller that rebuilds
    it per recording re-traces and re-compiles — that is part of the
    legacy loop driver's cost profile. The scanned driver
    (:func:`make_scan_fn`) is memoized per config instead.
    """
    hist_fn = _histogram_fn(config)
    metrics_fn = _metrics_fn(config)

    @jax.jit
    def process_window(batch: EventBatch) -> tuple[Clusters, dict[str, jax.Array]]:
        return _window_core(config, hist_fn, metrics_fn, batch)

    return process_window


@dataclasses.dataclass
class WindowResult:
    t_start_us: int
    clusters: Clusters  # device arrays, K slots
    metrics: dict[str, np.ndarray]
    tracks: TrackState | None = None


def run_recording(
    recording: Recording,
    config: PipelineConfig = PipelineConfig(),
    with_tracking: bool = True,
) -> list[WindowResult]:
    """Host driver: dual-threshold batching + jit'd window stage + tracker.

    One dispatch per window; see :func:`run_recording_scan` for the
    device-resident path with one dispatch per recording.
    """
    process_window = make_process_window(config)
    tracker_fn = jax.jit(functools.partial(tracker_step, config=config.tracker))
    state = init_tracks(config.tracker)
    results: list[WindowResult] = []
    for batch, sl in dual_threshold_batches(
        recording.x, recording.y, recording.t, recording.p, config.batcher
    ):
        clusters, mets = process_window(batch)
        if with_tracking:
            state, _ = tracker_fn(state, clusters, mets["shannon_entropy"])
        results.append(
            WindowResult(
                t_start_us=int(recording.t[sl.start]),
                clusters=clusters,
                metrics={k: np.asarray(v) for k, v in mets.items()},
                tracks=state if with_tracking else None,
            )
        )
    return results


# ---------------------------------------------------------------------------
# Device-resident scanned pipeline (one dispatch per recording).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ScanResult:
    """Stacked outputs of the scanned pipeline.

    ``clusters`` leaves and ``metrics`` values have shape (W, K);
    ``tracks`` leaves (when tracking is on) have shape (W, T) — the
    tracker state *after* each window. Everything stays on device until
    the caller converts it; ``window_results()`` materializes the legacy
    per-window list for drop-in comparisons.
    """

    t_start_us: np.ndarray  # (W,) int64
    clusters: Clusters  # leaves (W, K)
    metrics: dict[str, jax.Array]  # (W, K)
    tracks: TrackState | None  # leaves (W, T)
    final_tracks: TrackState | None
    windows: WindowedEvents

    @property
    def num_windows(self) -> int:
        return int(self.t_start_us.shape[0])

    def window_results(self) -> list[WindowResult]:
        mets_np = {k: np.asarray(v) for k, v in self.metrics.items()}
        out: list[WindowResult] = []
        for w in range(self.num_windows):
            out.append(
                WindowResult(
                    t_start_us=int(self.t_start_us[w]),
                    clusters=jax.tree.map(lambda a: a[w], self.clusters),
                    metrics={k: v[w] for k, v in mets_np.items()},
                    tracks=(
                        jax.tree.map(lambda a: a[w], self.tracks)
                        if self.tracks is not None
                        else None
                    ),
                )
            )
        return out


def _make_scan_core(config: PipelineConfig, with_tracking: bool):
    """Plain (un-jitted) scan function; jit/vmap wrappers are layered on top.

    ``metrics_impl="event"`` routes to the phased event-space driver
    (:func:`_make_event_scan_core`); "frame" and "kernel" keep the
    straight per-window scan.
    """
    if config.metrics_impl == "event":
        return _make_event_scan_core(config, with_tracking)
    hist_fn = _histogram_fn(config)
    metrics_fn = _metrics_fn(config)

    def scan_core(stacked: EventBatch, state: TrackState):
        def step(carry, batch):
            clusters, mets = _window_core(config, hist_fn, metrics_fn, batch)
            if with_tracking:
                carry, _ = tracker_step(
                    carry, clusters, mets["shannon_entropy"], config.tracker
                )
            return carry, (clusters, mets, carry)

        final, (clusters, mets, states) = jax.lax.scan(step, state, stacked)
        return final, clusters, mets, states

    return scan_core


def _make_event_scan_core(config: PipelineConfig, with_tracking: bool):
    """Event-space scan driver: O(events + K * patch^2) per window.

    Three phases, all inside one jit (DESIGN.md Sec. 5):

    1. **Batched conditioning + clustering + event stats** — windows are
       processed in ``scan_chunk`` blocks under ``lax.map`` so the
       pairwise hot-pixel filter, cell histogram, coincidence sort, and
       histogram matmul vectorize across windows while staying
       cache-resident.
    2. **Event-surface scan** — a persistent sensor-sized int32 surface
       rides the scan carry; each window writes its <= E leader pixels
       tagged with the window index (O(E), no per-window clear — stale
       pixels fail the tag check) and slices K count patches back out.
       This is the BRAM-resident accumulator a fabric implementation
       would use: memory is O(sensor), but per-window work is
       O(E + K * patch^2). The shared exact metric core and the tracker
       run in the same scan step.
    3. Outputs are truncated back to the true window count.

    Results are bit-identical to the frame-based scan driver.
    """
    hist_fn = _histogram_fn(config)
    grid = config.grid
    width, height = grid.width, grid.height
    window = M.WINDOW

    def scan_core(stacked: EventBatch, state: TrackState):
        w_total, cap = stacked.x.shape
        chunk = max(1, min(config.scan_chunk, max(w_total, 1)))
        pad = (-w_total) % chunk
        if pad:
            padded = jax.tree.map(
                lambda a: jnp.concatenate(
                    [a, jnp.zeros((pad,) + a.shape[1:], a.dtype)], axis=0
                ),
                stacked,
            )
        else:
            padded = stacked
        w_pad = w_total + pad
        n_chunks = w_pad // chunk
        chunked = jax.tree.map(
            lambda a: a.reshape(n_chunks, chunk, *a.shape[1:]), padded
        )

        def phase_window(batch: EventBatch):
            batch = _condition(config, batch)
            clusters = _cluster(config, hist_fn, batch)
            c, leader, wmask, norm = M.event_normalizer(batch, width, height)
            x0, y0 = M.window_origin(
                clusters.centroid_x, clusters.centroid_y, width, height
            )
            hist, moments = M.event_histogram_counts(
                batch, c, leader, wmask, norm, x0, y0
            )
            return (batch.x, batch.y, c, leader, norm, x0, y0, hist, moments, clusters)

        outs = jax.lax.map(lambda cb: jax.vmap(phase_window)(cb), chunked)
        outs = jax.tree.map(lambda a: a.reshape(w_pad, *a.shape[2:]), outs)
        ex, ey, c, leader, norm, x0, y0, hist, moments, clusters = outs

        # Phase 2: persistent tagged event surface + metrics + tracker.
        cols = max(width, cap)
        shift = max(cap.bit_length(), 1)  # pixel counts fit in `shift` bits
        mask = (1 << shift) - 1
        dump_x = jnp.arange(cap, dtype=jnp.int32)

        kmax = grid.max_clusters

        def window_patches(atlas, inp):
            """One window: tag-write leader pixels, slice K count patches."""
            tag, bx, by, lead, c_w, x0w, y0w = inp
            enc = jnp.where(lead, ((tag + 1) << shift) | (c_w & mask), 0)
            ix = jnp.where(lead, bx, dump_x)
            iy = jnp.where(lead, by, height)
            atlas = atlas.at[iy, ix].set(
                enc, unique_indices=True, mode="promise_in_bounds"
            )

            def one_patch(x0k, y0k):
                tile = jax.lax.dynamic_slice(atlas, (y0k, x0k), (window, window))
                return jnp.where(
                    (tile >> shift) == tag + 1, tile & mask, 0
                ).astype(jnp.float32)

            return atlas, jax.vmap(one_patch)(x0w, y0w)

        def chunk_step(atlas, inp):
            """One chunk: per-window patch extraction (sequential, shares
            the surface), then the dense metric core batched over the
            whole (chunk * K) patch block for vector width."""
            tag, bx, by, lead, c_w, norm_w, x0w, y0w, hist_w, mom_w, cl = inp
            atlas, patches = jax.lax.scan(
                window_patches, atlas, (tag, bx, by, lead, c_w, x0w, y0w)
            )
            mets = jax.vmap(M._exact_cluster_metrics)(
                patches.reshape(chunk * kmax, window, window),
                hist_w.reshape(chunk * kmax, -1),
                jnp.repeat(norm_w, kmax),
                cl.count.reshape(chunk * kmax),
                cl.valid.reshape(chunk * kmax),
                jax.tree.map(lambda a: a.reshape(chunk * kmax), mom_w),
            )
            return atlas, {k: v.reshape(chunk, kmax) for k, v in mets.items()}

        atlas0 = jnp.zeros((height + 1, cols), jnp.int32)
        tags = jnp.arange(w_pad, dtype=jnp.int32)
        rechunk = lambda a: a.reshape(n_chunks, chunk, *a.shape[1:])
        _, mets = jax.lax.scan(
            chunk_step,
            atlas0,
            jax.tree.map(
                rechunk,
                (tags, ex, ey, leader, c, norm, x0, y0, hist, moments, clusters),
            ),
        )
        mets = {k: v.reshape(w_pad, kmax) for k, v in mets.items()}

        # Truncate the chunk padding, then track over the true windows only.
        trim = lambda a: a[:w_total]
        clusters = jax.tree.map(trim, clusters)
        mets = {k: trim(v) for k, v in mets.items()}

        if with_tracking:
            def track_step(carry, inp):
                cl, shannon = inp
                carry, _ = tracker_step(carry, cl, shannon, config.tracker)
                return carry, carry

            final, states = jax.lax.scan(
                track_step, state, (clusters, mets["shannon_entropy"])
            )
        else:
            final = state
            states = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (w_total,) + a.shape), state
            )
        return final, clusters, mets, states

    return scan_core


@functools.lru_cache(maxsize=None)
def make_scan_fn(config: PipelineConfig = PipelineConfig(), with_tracking: bool = True):
    """Jit'd whole-recording scan: (stacked EventBatch, init TrackState) ->
    (final TrackState, stacked Clusters, stacked metrics, stacked TrackState).

    Compiled once per (config, window count, capacity); cached per config.
    """
    return jax.jit(_make_scan_core(config, with_tracking))


@functools.lru_cache(maxsize=None)
def _make_many_scan_fn(config: PipelineConfig, with_tracking: bool):
    core = _make_scan_core(config, with_tracking)
    # Map over the recording axis; broadcast the (fresh) tracker state.
    return jax.jit(jax.vmap(core, in_axes=(0, None)))


def run_recording_scan(
    recording: Recording,
    config: PipelineConfig = PipelineConfig(),
    with_tracking: bool = True,
    windows: WindowedEvents | None = None,
) -> ScanResult:
    """Device-resident driver: the whole recording in one ``lax.scan``.

    Windows are identical to :func:`run_recording`'s dual-threshold
    batches (same boundaries, same padding), but the per-window stage and
    the tracker run inside a single compiled scan — one host->device
    transfer in, one device->host sync out, no per-window dispatch.
    Pass a precomputed ``windows`` (from :func:`pad_windows`) to skip the
    host windowing pass, e.g. when sweeping configs over one recording.
    """
    if windows is None:
        windows = pad_windows(
            recording.x, recording.y, recording.t, recording.p, config.batcher
        )
    scan_fn = make_scan_fn(config, with_tracking)
    final, clusters, mets, states = scan_fn(windows.batch, init_tracks(config.tracker))
    return ScanResult(
        t_start_us=windows.t_start_us,
        clusters=clusters,
        metrics=mets,
        tracks=states if with_tracking else None,
        final_tracks=final if with_tracking else None,
        windows=windows,
    )


def run_many_scan(
    recordings: list[Recording],
    config: PipelineConfig = PipelineConfig(),
    with_tracking: bool = True,
) -> list[ScanResult]:
    """Vmapped scan over a batch of recordings (multi-sensor throughput).

    Recordings are windowed on host, right-padded with empty (all-invalid)
    windows to a common window count, stacked to (R, W, capacity) leaves,
    and pushed through ``vmap(scan)`` in a single dispatch. Results are
    split back per recording and trimmed to each one's true window count.
    """
    if not recordings:
        return []
    windowed = [
        pad_windows(r.x, r.y, r.t, r.p, config.batcher) for r in recordings
    ]
    w_max = max(w.num_windows for w in windowed)

    def pad_leaf(a: jax.Array) -> jax.Array:
        pad = w_max - a.shape[0]
        if pad == 0:
            return a
        return jnp.concatenate(
            [a, jnp.zeros((pad,) + a.shape[1:], a.dtype)], axis=0
        )

    stacked = EventBatch(
        *[
            jnp.stack([pad_leaf(getattr(w.batch, f)) for w in windowed])
            for f in EventBatch._fields
        ]
    )
    many_fn = _make_many_scan_fn(config, with_tracking)
    _, clusters, mets, states = many_fn(stacked, init_tracks(config.tracker))
    results: list[ScanResult] = []
    for r, w in enumerate(windowed):
        n = w.num_windows
        if not with_tracking:
            final_r = None
        elif n == 0:
            final_r = init_tracks(config.tracker)
        else:
            # The scan carry after w_max windows has coasted through this
            # recording's padded (all-invalid) tail; the true final state
            # is the per-window state at its last real window.
            final_r = jax.tree.map(lambda a: a[r, n - 1], states)
        results.append(
            ScanResult(
                t_start_us=w.t_start_us,
                clusters=jax.tree.map(lambda a: a[r, :n], clusters),
                metrics={k: v[r, :n] for k, v in mets.items()},
                tracks=(
                    jax.tree.map(lambda a: a[r, :n], states)
                    if with_tracking
                    else None
                ),
                final_tracks=final_r,
                windows=w,
            )
        )
    return results


# ---------------------------------------------------------------------------
# Accuracy evaluation (paper Sec. V-A: sampled detections vs ground truth).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DetectionScore:
    tp: int = 0  # cluster >= threshold and is a true RSO
    fp: int = 0  # cluster >= threshold but star/noise
    fn: int = 0  # candidate RSO cluster rejected by threshold
    tn: int = 0  # star/noise candidate correctly rejected

    @property
    def accuracy(self) -> float:
        total = self.tp + self.fp + self.fn + self.tn
        return (self.tp + self.tn) / total if total else 0.0

    @property
    def precision(self) -> float:
        d = self.tp + self.fp
        return self.tp / d if d else 0.0

    @property
    def recall(self) -> float:
        d = self.tp + self.fn
        return self.tp / d if d else 0.0


@dataclasses.dataclass
class Candidates:
    """Pipeline outputs collected once; thresholds are swept afterwards.

    Cluster level: every candidate cluster (>= candidate_floor events) with
    its event count and ground-truth flag (centroid within the gate radius
    of a true RSO position at the cluster's mean time).

    Object level: for every (window, visible RSO) pair, the best (max)
    count among clusters matched to that RSO — used for miss (FN) scoring,
    mirroring the paper's protocol of verifying detections against known
    RSO *trajectories* rather than counting sub-threshold fragments of an
    already-detected object as misses.
    """

    counts: np.ndarray  # (C,) candidate cluster event counts
    is_rso: np.ndarray  # (C,) bool
    object_best: np.ndarray  # (V,) best matched count per visible-object-window

def collect_candidates(
    recording: Recording,
    config: PipelineConfig = PipelineConfig(),
    candidate_floor: int = 2,
    max_samples: int | None = None,
    gate_px: float = 14.0,
    min_truth_events: int = 3,
) -> Candidates:
    """Run the scanned pipeline ONCE over a recording and collect candidates.

    Truth matching is vectorized: RSO trajectory positions are evaluated
    for every (window, cluster slot, object) triple in one numpy pass
    instead of the per-cluster Python loop of
    :func:`collect_candidates_loop` (kept as the reference oracle).
    Ordering, ``max_samples`` truncation, and object-level bookkeeping
    match the loop exactly.
    """
    from repro.data.synthetic import KIND_RSO

    floor_grid = dataclasses.replace(config.grid, min_events=candidate_floor)
    floor_cfg = dataclasses.replace(config, grid=floor_grid)
    result = run_recording_scan(recording, floor_cfg, with_tracking=False)
    windows = result.windows

    counts = np.asarray(result.clusters.count)  # (W, K)
    valid = np.asarray(result.clusters.valid)
    cx = np.asarray(result.clusters.centroid_x, np.float64)
    cy = np.asarray(result.clusters.centroid_y, np.float64)
    ct = np.asarray(result.clusters.centroid_t, np.float64)
    w_count, k = counts.shape if counts.ndim == 2 else (0, 0)

    tracks = np.asarray(recording.rso_tracks, np.float64).reshape(-1, 4)
    n_rso = tracks.shape[0]

    # Cluster-level: match every (window, slot) centroid against every RSO
    # trajectory at the cluster's mean event time.
    t_ev = windows.t_start_us[:, None].astype(np.float64) + ct  # (W, K)
    ts = t_ev[:, :, None] * 1e-6  # seconds, (W, K, 1)
    px = tracks[None, None, :, 0] + tracks[None, None, :, 2] * ts  # (W, K, R)
    py = tracks[None, None, :, 1] + tracks[None, None, :, 3] * ts
    matched = (
        np.hypot(px - cx[:, :, None], py - cy[:, :, None]) <= gate_px
    )  # (W, K, R)

    # Candidate ordering is window-major, slot order — same as the loop.
    flat_valid = valid.reshape(-1)
    if max_samples is None:
        keep_flat = flat_valid
    else:
        rank = np.cumsum(flat_valid) - 1
        keep_flat = flat_valid & (rank < max_samples)
    keep = keep_flat.reshape(w_count, k)
    counts_out = counts.reshape(-1)[keep_flat].astype(np.int32)
    is_rso = matched.any(axis=-1).reshape(-1)[keep_flat]

    # Object-level: per (window, RSO) visible pair, the best matched count
    # among kept clusters. Visibility = >= min_truth_events true RSO events
    # inside the window's slice of the recording.
    n_true = np.zeros((w_count, n_rso), np.int64)
    rso_ev = np.flatnonzero(np.asarray(recording.kind) == KIND_RSO)
    if rso_ev.size and w_count:
        # Dual-threshold windows partition the stream: event e lands in the
        # window whose stop is the first one strictly past e. Events past
        # the last stop (none, by construction) are dropped defensively.
        ev_w = np.searchsorted(windows.stops, rso_ev, side="right")
        in_range = ev_w < w_count
        np.add.at(
            n_true,
            (ev_w[in_range], np.asarray(recording.obj)[rso_ev[in_range]]),
            1,
        )
    visible = n_true >= min_truth_events  # (W, R)
    contrib = np.where(
        matched & keep[:, :, None], counts[:, :, None], 0
    )  # (W, K, R)
    best = contrib.max(axis=1) if k else np.zeros((w_count, n_rso), counts.dtype)
    object_best = best[visible]

    return Candidates(
        counts_out,
        np.asarray(is_rso, bool),
        np.asarray(object_best, np.int32),
    )


def collect_candidates_loop(
    recording: Recording,
    config: PipelineConfig = PipelineConfig(),
    candidate_floor: int = 2,
    max_samples: int | None = None,
    gate_px: float = 14.0,
    min_truth_events: int = 3,
) -> Candidates:
    """Legacy per-window/per-cluster Python loop (reference oracle).

    Semantically identical to :func:`collect_candidates`; kept so the
    vectorized path stays testable against first-principles code.
    """
    from repro.data.synthetic import KIND_RSO

    floor_grid = dataclasses.replace(config.grid, min_events=candidate_floor)
    floor_cfg = dataclasses.replace(config, grid=floor_grid)
    process_window = make_process_window(floor_cfg)
    counts_out: list[int] = []
    truth_out: list[bool] = []
    object_best: list[int] = []
    n_rso = np.asarray(recording.rso_tracks).reshape(-1, 4).shape[0]

    for batch, sl in dual_threshold_batches(
        recording.x, recording.y, recording.t, recording.p, floor_cfg.batcher
    ):
        clusters, _ = process_window(batch)
        counts = np.asarray(clusters.count)
        valid = np.asarray(clusters.valid)
        cxs = np.asarray(clusters.centroid_x)
        cys = np.asarray(clusters.centroid_y)
        cts = np.asarray(clusters.centroid_t)
        t0 = float(recording.t[sl.start])
        # Object-level bookkeeping: best matched count per visible RSO.
        kinds = recording.kind[sl]
        objs = recording.obj[sl]
        best = {}
        for r in range(n_rso):
            n_true = int(np.sum((kinds == KIND_RSO) & (objs == r)))
            if n_true >= min_truth_events:
                best[r] = 0
        for k in range(len(counts)):
            if not valid[k]:
                continue
            if max_samples is not None and len(counts_out) >= max_samples:
                break
            cx, cy = float(cxs[k]), float(cys[k])
            t_ev = t0 + float(cts[k])
            matched = False
            for r in range(n_rso):
                px, py = recording.rso_position(r, np.array([t_ev]))
                if np.hypot(px[0] - cx, py[0] - cy) <= gate_px:
                    matched = True
                    if r in best:
                        best[r] = max(best[r], int(counts[k]))
            counts_out.append(int(counts[k]))
            truth_out.append(matched)
        object_best.extend(best.values())
    return Candidates(
        np.asarray(counts_out, np.int32),
        np.asarray(truth_out, bool),
        np.asarray(object_best, np.int32),
    )


def score_threshold(cand: Candidates, thr: int) -> DetectionScore:
    passed = cand.counts >= thr
    return DetectionScore(
        tp=int(np.sum(passed & cand.is_rso)),
        fp=int(np.sum(passed & ~cand.is_rso)),
        fn=int(np.sum(cand.object_best < thr)),
        tn=int(np.sum(~passed & ~cand.is_rso)),
    )


def merge_candidates(cands: list[Candidates]) -> Candidates:
    return Candidates(
        np.concatenate([c.counts for c in cands]) if cands else np.zeros(0, np.int32),
        np.concatenate([c.is_rso for c in cands]) if cands else np.zeros(0, bool),
        np.concatenate([c.object_best for c in cands]) if cands else np.zeros(0, np.int32),
    )


def evaluate_detection(
    recording: Recording,
    config: PipelineConfig = PipelineConfig(),
    min_events: int | None = None,
    candidate_floor: int = 2,
    max_samples: int | None = None,
) -> DetectionScore:
    """Score the min_events detector against simulator ground truth
    (the paper's Fig. 10b / Sec. V-A protocol)."""
    thr = config.grid.min_events if min_events is None else min_events
    cand = collect_candidates(recording, config, candidate_floor, max_samples)
    return score_threshold(cand, thr)


def threshold_sweep(
    recordings: list[Recording],
    thresholds: tuple[int, ...] = (2, 3, 4, 5, 6, 8, 10),
    config: PipelineConfig = PipelineConfig(),
    max_samples_per_recording: int | None = None,
) -> dict[int, DetectionScore]:
    """Accuracy vs min_events across a validation suite (paper Fig. 10b).

    The scanned pipeline runs ONCE per recording (one dispatch each);
    thresholds are swept over the collected candidates (the O(n)
    single-pass property in action).
    """
    cand = merge_candidates(
        [
            collect_candidates(rec, config, max_samples=max_samples_per_recording)
            for rec in recordings
        ]
    )
    return {thr: score_threshold(cand, thr) for thr in thresholds}
