"""End-to-end RSO detection pipeline (paper Fig. 2).

Stages, matching the paper's data flow:

  event capture -> conditioning (ROI + persistent-event removal)
    -> spatial quantization        [FPGA IP core -> Pallas kernel / jnp]
    -> cluster formation           [client software -> scatter + top-k]
    -> min_events threshold + metrics
    -> tracking (spatial-coherence validation)

``process_window`` is the jit'd per-window function;
``run_recording`` drives it with the dual-threshold batcher and scans the
tracker across windows; ``evaluate_detection`` scores accuracy against
ground truth exactly as the paper does (sampled detections manually
verified -> here verified against simulator truth).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics as M
from repro.core.events import (
    DEFAULT_ROI,
    BatcherConfig,
    EventBatch,
    dual_threshold_batches,
    persistent_event_filter,
    roi_filter,
)
from repro.core.grid_clustering import (
    Clusters,
    GridConfig,
    cell_histogram,
    clusters_from_histogram,
    merge_adjacent,
)
from repro.core.tracking import TrackerConfig, TrackState, init_tracks, tracker_step

from typing import TYPE_CHECKING

if TYPE_CHECKING:  # avoid circular import (data.synthetic uses core.events)
    from repro.data.synthetic import Recording


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    grid: GridConfig = GridConfig()
    batcher: BatcherConfig = BatcherConfig()
    tracker: TrackerConfig = TrackerConfig()
    roi: tuple[int, int, int, int] = DEFAULT_ROI
    hot_pixel_max: int = 12
    merge_neighbors: bool = False
    use_kernels: bool = False  # route quantize+accumulate through Pallas


def _histogram_fn(config: PipelineConfig) -> Callable[[EventBatch], tuple]:
    if config.use_kernels:
        # Imported lazily: kernels are optional at pipeline import time.
        from repro.kernels import ops as kops

        def fn(batch: EventBatch):
            return kops.cluster_accum(
                batch.x, batch.y, batch.t, batch.valid,
                cell_size=config.grid.cell_size,
                grid_w=config.grid.grid_w,
                grid_h=config.grid.grid_h,
            )

        return fn
    return lambda batch: cell_histogram(batch, config.grid)


def make_process_window(config: PipelineConfig = PipelineConfig()):
    """Build the jit'd per-window stage: conditioning -> clusters -> metrics."""
    hist_fn = _histogram_fn(config)

    @jax.jit
    def process_window(batch: EventBatch) -> tuple[Clusters, dict[str, jax.Array]]:
        batch = roi_filter(batch, config.roi)
        batch = persistent_event_filter(batch, config.hot_pixel_max)
        count, sx, sy, st = hist_fn(batch)
        clusters = clusters_from_histogram(count, sx, sy, st, config.grid)
        if config.merge_neighbors:
            clusters = merge_adjacent(clusters, config.grid)
        frame = M.reconstruct_frame(batch, config.grid.width, config.grid.height)
        mets = M.cluster_metrics(frame, clusters)
        return clusters, mets

    return process_window


@dataclasses.dataclass
class WindowResult:
    t_start_us: int
    clusters: Clusters  # device arrays, K slots
    metrics: dict[str, np.ndarray]
    tracks: TrackState | None = None


def run_recording(
    recording: Recording,
    config: PipelineConfig = PipelineConfig(),
    with_tracking: bool = True,
) -> list[WindowResult]:
    """Host driver: dual-threshold batching + jit'd window stage + tracker."""
    process_window = make_process_window(config)
    tracker_fn = jax.jit(partial(tracker_step, config=config.tracker))
    state = init_tracks(config.tracker)
    results: list[WindowResult] = []
    for batch, sl in dual_threshold_batches(
        recording.x, recording.y, recording.t, recording.p, config.batcher
    ):
        clusters, mets = process_window(batch)
        if with_tracking:
            state, _ = tracker_fn(state, clusters, mets["shannon_entropy"])
        results.append(
            WindowResult(
                t_start_us=int(recording.t[sl.start]),
                clusters=clusters,
                metrics={k: np.asarray(v) for k, v in mets.items()},
                tracks=state if with_tracking else None,
            )
        )
    return results


# ---------------------------------------------------------------------------
# Accuracy evaluation (paper Sec. V-A: sampled detections vs ground truth).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DetectionScore:
    tp: int = 0  # cluster >= threshold and is a true RSO
    fp: int = 0  # cluster >= threshold but star/noise
    fn: int = 0  # candidate RSO cluster rejected by threshold
    tn: int = 0  # star/noise candidate correctly rejected

    @property
    def accuracy(self) -> float:
        total = self.tp + self.fp + self.fn + self.tn
        return (self.tp + self.tn) / total if total else 0.0

    @property
    def precision(self) -> float:
        d = self.tp + self.fp
        return self.tp / d if d else 0.0

    @property
    def recall(self) -> float:
        d = self.tp + self.fn
        return self.tp / d if d else 0.0


def _cluster_truth(
    recording: Recording, t_us: float, cx: float, cy: float, radius: float = 14.0
) -> bool:
    """Is there a true RSO within ``radius`` px of (cx, cy) at time t?"""
    for r in range(recording.rso_tracks.shape[0]):
        px, py = recording.rso_position(r, np.array([t_us]))
        if np.hypot(px[0] - cx, py[0] - cy) <= radius:
            return True
    return False


@dataclasses.dataclass
class Candidates:
    """Pipeline outputs collected once; thresholds are swept afterwards.

    Cluster level: every candidate cluster (>= candidate_floor events) with
    its event count and ground-truth flag (centroid within the gate radius
    of a true RSO position at the cluster's mean time).

    Object level: for every (window, visible RSO) pair, the best (max)
    count among clusters matched to that RSO — used for miss (FN) scoring,
    mirroring the paper's protocol of verifying detections against known
    RSO *trajectories* rather than counting sub-threshold fragments of an
    already-detected object as misses.
    """

    counts: np.ndarray  # (C,) candidate cluster event counts
    is_rso: np.ndarray  # (C,) bool
    object_best: np.ndarray  # (V,) best matched count per visible-object-window


def collect_candidates(
    recording: Recording,
    config: PipelineConfig = PipelineConfig(),
    candidate_floor: int = 2,
    max_samples: int | None = None,
    gate_px: float = 14.0,
    min_truth_events: int = 3,
) -> Candidates:
    """Run the pipeline ONCE over a recording and collect candidates."""
    from repro.data.synthetic import KIND_RSO

    floor_grid = dataclasses.replace(config.grid, min_events=candidate_floor)
    floor_cfg = dataclasses.replace(config, grid=floor_grid)
    process_window = make_process_window(floor_cfg)
    counts_out: list[int] = []
    truth_out: list[bool] = []
    object_best: list[int] = []
    n_rso = recording.rso_tracks.shape[0]
    from repro.core.events import dual_threshold_batches as _batches

    for batch, sl in _batches(
        recording.x, recording.y, recording.t, recording.p, floor_cfg.batcher
    ):
        clusters, _ = process_window(batch)
        counts = np.asarray(clusters.count)
        valid = np.asarray(clusters.valid)
        cxs = np.asarray(clusters.centroid_x)
        cys = np.asarray(clusters.centroid_y)
        cts = np.asarray(clusters.centroid_t)
        t0 = float(recording.t[sl.start])
        t_mid = t0 + 0.5 * float(recording.t[sl.stop - 1] - recording.t[sl.start])
        # Object-level bookkeeping: best matched count per visible RSO.
        kinds = recording.kind[sl]
        objs = recording.obj[sl]
        best = {}
        for r in range(n_rso):
            n_true = int(np.sum((kinds == KIND_RSO) & (objs == r)))
            if n_true >= min_truth_events:
                best[r] = 0
        for k in range(len(counts)):
            if not valid[k]:
                continue
            if max_samples is not None and len(counts_out) >= max_samples:
                break
            cx, cy = float(cxs[k]), float(cys[k])
            t_ev = t0 + float(cts[k])
            matched = False
            for r in range(n_rso):
                px, py = recording.rso_position(r, np.array([t_ev]))
                if np.hypot(px[0] - cx, py[0] - cy) <= gate_px:
                    matched = True
                    if r in best:
                        best[r] = max(best[r], int(counts[k]))
            counts_out.append(int(counts[k]))
            truth_out.append(matched)
        object_best.extend(best.values())
    return Candidates(
        np.asarray(counts_out, np.int32),
        np.asarray(truth_out, bool),
        np.asarray(object_best, np.int32),
    )


def score_threshold(cand: Candidates, thr: int) -> DetectionScore:
    passed = cand.counts >= thr
    return DetectionScore(
        tp=int(np.sum(passed & cand.is_rso)),
        fp=int(np.sum(passed & ~cand.is_rso)),
        fn=int(np.sum(cand.object_best < thr)),
        tn=int(np.sum(~passed & ~cand.is_rso)),
    )


def merge_candidates(cands: list[Candidates]) -> Candidates:
    return Candidates(
        np.concatenate([c.counts for c in cands]) if cands else np.zeros(0, np.int32),
        np.concatenate([c.is_rso for c in cands]) if cands else np.zeros(0, bool),
        np.concatenate([c.object_best for c in cands]) if cands else np.zeros(0, np.int32),
    )


def evaluate_detection(
    recording: Recording,
    config: PipelineConfig = PipelineConfig(),
    min_events: int | None = None,
    candidate_floor: int = 2,
    max_samples: int | None = None,
) -> DetectionScore:
    """Score the min_events detector against simulator ground truth
    (the paper's Fig. 10b / Sec. V-A protocol)."""
    thr = config.grid.min_events if min_events is None else min_events
    cand = collect_candidates(recording, config, candidate_floor, max_samples)
    return score_threshold(cand, thr)


def threshold_sweep(
    recordings: list[Recording],
    thresholds: tuple[int, ...] = (2, 3, 4, 5, 6, 8, 10),
    config: PipelineConfig = PipelineConfig(),
    max_samples_per_recording: int | None = None,
) -> dict[int, DetectionScore]:
    """Accuracy vs min_events across a validation suite (paper Fig. 10b).

    The pipeline runs ONCE per recording; thresholds are swept over the
    collected candidates (the O(n) single-pass property in action).
    """
    cand = merge_candidates(
        [
            collect_candidates(rec, config, max_samples=max_samples_per_recording)
            for rec in recordings
        ]
    )
    return {thr: score_threshold(cand, thr) for thr in thresholds}
